//! The Lorenz96 digital twin (Fig. 4): an autonomously evolving
//! six-dimensional atmospheric model.
//!
//! Backends: analogue solver, Rust RK4, the recurrent baselines
//! (RNN/GRU/LSTM, Fig. 4g-i), or the AOT PJRT artifact.
//!
//! Like the HP twin, the batched request path draws every buffer —
//! grouping, flat initial states, the lockstep rollout and the per-request
//! response trajectories — from reusable twin-owned scratch, so a warm
//! `run_batch` performs no steady-state heap allocations on the Analog
//! and Digital backends.

use anyhow::Result;

use crate::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use crate::device::taox::DeviceConfig;
use crate::models::gru::Gru;
use crate::models::loader::{MlpWeights, RnnWeights};
use crate::models::lstm::Lstm;
use crate::models::mlp::{BatchMlpField, Mlp, MlpField};
use crate::models::rnn::{Recurrent, VanillaRnn};
use crate::ode::batch::unbatch_into;
use crate::ode::rk4::{self, Rk4};
use crate::twin::shard::{
    ShardExecutor, ShardGroup, ShardSnapshot, ShardedAnalogOde,
};
use crate::twin::{
    assemble_ensemble_stats, ensemble_member_seed, EnsembleStats, GroupPlan,
    RolloutFn, Twin, TwinRequest, TwinResponse, MAX_SUB_BATCH_LANES,
};
use crate::util::rng::{NoiseLane, SeedSequencer};
use crate::util::stats::EnsembleAccumulator;
use crate::util::tensor::{Trajectory, TrajectoryPool};
use crate::workload::lorenz96;

/// Default circuit substeps per output sample for the analogue backend.
pub const ANALOG_SUBSTEPS: usize = 20;
/// RK4 substeps per output sample for the digital backend.
pub const DIGITAL_SUBSTEPS: usize = 1;

/// Auto-seed root for backends built without an explicit seed (digital,
/// recurrent, pjrt — the seed is still resolved and echoed for replay).
const L96_AUTO_ROOT: u64 = 0x1963_5eed_0000_0002;

/// Execution backend of the Lorenz96 twin.
pub enum L96Backend {
    Analog(Box<AnalogNeuralOde>),
    /// Tile-sharded fan-out: one rollout spread across parallel shard
    /// workers (states wider than one physical array).
    AnalogSharded(Box<ShardedAnalogOde>),
    Digital(Mlp),
    Recurrent(Box<dyn Recurrent + Send>),
    Pjrt(RolloutFn),
}

impl L96Backend {
    fn label(&self) -> &'static str {
        match self {
            L96Backend::Analog(_) => "analog",
            L96Backend::AnalogSharded(_) => "analog-sharded",
            L96Backend::Digital(_) => "digital-rk4",
            L96Backend::Recurrent(_) => "recurrent",
            L96Backend::Pjrt(_) => "pjrt",
        }
    }
}

/// Analogue-backend options: circuit substeps plus the tile-shard layout.
#[derive(Debug, Clone)]
pub struct L96AnalogOpts {
    /// Circuit substeps per output sample.
    pub substeps: usize,
    /// Shard count; 0 or 1 keeps the monolithic kernel.
    pub shards: usize,
    /// Fan shards out across parallel shard workers
    /// ([`ShardedAnalogOde`]); `false` runs the serial sharded kernel
    /// inside [`AnalogNeuralOde`] (zero-allocation warm path).
    pub parallel: bool,
}

impl Default for L96AnalogOpts {
    fn default() -> Self {
        Self { substeps: ANALOG_SUBSTEPS, shards: 1, parallel: false }
    }
}

/// Reusable batch scratch (see `HpScratch` — same shape, flat dim-`d`
/// initial states instead of scalar ones).
#[derive(Default)]
struct L96Scratch {
    plan: GroupPlan,
    slots: Vec<Option<Result<TwinResponse>>>,
    members: Vec<usize>,
    /// First lane slot of each valid request within the group's flat
    /// batch (an ensemble request occupies `lanes()` consecutive slots).
    lane_base: Vec<usize>,
    /// Flat `[lanes * dim]` initial states of the current group (ensemble
    /// members replicate their request's h0).
    h0s: Vec<f64>,
    /// Per-request resolved noise seeds (echoed in the responses; an
    /// ensemble's members derive from it via [`ensemble_member_seed`]).
    seeds: Vec<u64>,
    /// Per-lane noise lanes (one per trajectory, rebuilt from seeds).
    lanes: Vec<NoiseLane>,
    flat: Trajectory,
    pool: TrajectoryPool,
    /// Streaming ensemble moment accumulator (pooled output buffers).
    acc: EnsembleAccumulator,
    /// Recycled [`EnsembleStats`] container shells.
    ens_shells: Vec<EnsembleStats>,
    solver: L96SolverScratch,
}

/// Digital-backend solver scratch.
struct L96SolverScratch {
    rk4: Rk4,
}

impl Default for L96SolverScratch {
    fn default() -> Self {
        Self { rk4: Rk4::new(0) }
    }
}

/// The Lorenz96 twin.
pub struct Lorenz96Twin {
    backend: L96Backend,
    dt: f64,
    dim: usize,
    /// Dimension-appropriate default initial condition.
    default_h0: Vec<f64>,
    /// Auto-seed source for requests without an explicit noise seed.
    seeds: SeedSequencer,
    scratch: L96Scratch,
}

impl Lorenz96Twin {
    fn assemble(
        backend: L96Backend,
        dt: f64,
        dim: usize,
        lane_root: u64,
    ) -> Self {
        Self {
            backend,
            dt,
            dim,
            default_h0: lorenz96::default_y0(dim),
            seeds: SeedSequencer::new(lane_root),
            scratch: L96Scratch::default(),
        }
    }

    /// Analogue-backend twin from trained weights (monolithic kernel,
    /// paper-default substeps).
    pub fn analog(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        Self::analog_opts(weights, cfg, noise, seed, L96AnalogOpts::default())
    }

    /// Analogue-backend twin with explicit substeps and tile-shard layout.
    /// `opts.shards > 1` splits states wider than one physical array
    /// across tile column-groups; with `opts.parallel` the shards execute
    /// on parallel shard workers, otherwise serially in the solver. Both
    /// sharded forms are bit-identical to the monolithic kernel under
    /// noise-off deployment (asserted in `rust/tests/sharded.rs`).
    pub fn analog_opts(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        opts: L96AnalogOpts,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let dim = weights.layers.last().unwrap().0.cols;
        let mlp = AnalogMlp::deploy(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let substeps = opts.substeps.max(1);
        let ode = AnalogNeuralOde::new(mlp, dim, dt / substeps as f64);
        let backend = if opts.shards > 1 && opts.parallel {
            let sharded = ShardedAnalogOde::from_ode(
                &ode,
                ShardExecutor::new(opts.shards),
            );
            L96Backend::AnalogSharded(Box::new(sharded))
        } else if opts.shards > 1 {
            L96Backend::Analog(Box::new(ode.with_shards(opts.shards)))
        } else {
            L96Backend::Analog(Box::new(ode))
        };
        Self::assemble(backend, dt, dim, seed)
    }

    /// Analogue-backend twin on *mortal* hardware: deployed via
    /// [`AnalogMlp::deploy_aging`], so the crossbars keep their physical
    /// state and expose the virtual-clock lifetime API
    /// ([`Lorenz96Twin::advance_age`], [`Lorenz96Twin::recalibrate`], …).
    /// Monolithic kernel only — aging engines refresh in place, which the
    /// tile-shard execution forms do not support. At age 0 this twin is
    /// bit-identical to [`Lorenz96Twin::analog`] under the same seed.
    pub fn analog_aging(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let dim = weights.layers.last().unwrap().0.cols;
        let mlp = AnalogMlp::deploy_aging(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let substeps = substeps.max(1);
        let ode = AnalogNeuralOde::new(mlp, dim, dt / substeps as f64);
        Self::assemble(L96Backend::Analog(Box::new(ode)), dt, dim, seed)
    }

    /// The aging analogue deployment, if this twin was built with
    /// [`Lorenz96Twin::analog_aging`].
    fn aging_mlp(&mut self) -> Option<&mut AnalogMlp> {
        match &mut self.backend {
            L96Backend::Analog(ode) if ode.mlp.is_aging() => {
                Some(&mut ode.mlp)
            }
            _ => None,
        }
    }

    /// Whether this twin runs on mortal (aging) analogue hardware.
    pub fn is_aging(&self) -> bool {
        matches!(&self.backend, L96Backend::Analog(ode) if ode.mlp.is_aging())
    }

    /// Advance the hardware's virtual clock by `dt_s` seconds (drift +
    /// diffusion on every cell, engines refreshed). No-op for `dt_s <= 0`;
    /// panics on a non-aging twin.
    pub fn advance_age(&mut self, dt_s: f64) {
        self.aging_mlp()
            .expect("advance_age requires an analog_aging twin")
            .advance_age(dt_s);
    }

    /// Reprogram every array back to its target weights; returns the
    /// write-verify pulse count (energy via
    /// [`crate::energy::recalibration_energy`]).
    pub fn recalibrate(&mut self) -> u64 {
        self.aging_mlp()
            .expect("recalibrate requires an analog_aging twin")
            .recalibrate()
    }

    /// Virtual device age (s); 0 for immortal twins.
    pub fn age_s(&self) -> f64 {
        match &self.backend {
            L96Backend::Analog(ode) => ode.mlp.age_s(),
            _ => 0.0,
        }
    }

    /// Healthy-cell fraction across every deployed array (1.0 if
    /// immortal).
    pub fn array_health(&self) -> f64 {
        match &self.backend {
            L96Backend::Analog(ode) => ode.mlp.array_health(),
            _ => 1.0,
        }
    }

    /// Lifetime write-verify pulses spent on recalibration.
    pub fn lifetime_pulses(&self) -> u64 {
        match &self.backend {
            L96Backend::Analog(ode) => ode.mlp.lifetime_pulses(),
            _ => 0,
        }
    }

    /// Completed recalibration count.
    pub fn recalibrations(&self) -> u64 {
        match &self.backend {
            L96Backend::Analog(ode) => ode.mlp.recalibrations(),
            _ => 0,
        }
    }

    /// Mark a random `fraction` of cells stuck (fault-injection campaigns;
    /// deterministic in the deployment's aging stream). Panics on a
    /// non-aging twin.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        self.aging_mlp()
            .expect("inject_stuck_faults requires an analog_aging twin")
            .inject_stuck_faults(fraction);
    }

    /// Digital (Rust RK4) twin.
    pub fn digital(weights: &MlpWeights) -> Self {
        let dim = weights.layers.last().unwrap().0.cols;
        Self::assemble(
            L96Backend::Digital(Mlp::from_weights(weights)),
            weights.dt,
            dim,
            L96_AUTO_ROOT,
        )
    }

    /// Recurrent baseline twin ("rnn" | "gru" | "lstm").
    pub fn recurrent(weights: &RnnWeights) -> Result<Self> {
        let cell: Box<dyn Recurrent + Send> = match weights.kind.as_str() {
            "rnn" => Box::new(VanillaRnn::new(weights.clone())),
            "gru" => Box::new(Gru::new(weights.clone())),
            "lstm" => Box::new(Lstm::new(weights.clone())),
            other => anyhow::bail!("unknown recurrent kind '{other}'"),
        };
        Ok(Self::assemble(
            L96Backend::Recurrent(cell),
            weights.dt,
            weights.d_in,
            L96_AUTO_ROOT,
        ))
    }

    /// PJRT-artifact twin.
    pub fn pjrt(rollout: RolloutFn, dt: f64, dim: usize) -> Self {
        Self::assemble(L96Backend::Pjrt(rollout), dt, dim, L96_AUTO_ROOT)
    }

    /// Per-shard serving counters of the fan-out backend, if sharded.
    pub fn shard_telemetry(&self) -> Option<Vec<ShardSnapshot>> {
        match &self.backend {
            L96Backend::AnalogSharded(ode) => {
                Some(ode.telemetry().snapshot())
            }
            _ => None,
        }
    }

    /// Wire the fan-out backend's rollout counters into the coordinator's
    /// serving telemetry (no-op for unsharded backends).
    pub fn attach_coordinator_telemetry(
        &mut self,
        t: std::sync::Arc<crate::coordinator::telemetry::Telemetry>,
    ) {
        if let L96Backend::AnalogSharded(ode) = &mut self.backend {
            ode.attach_coordinator_telemetry(t);
        }
    }

    /// Toggle co-scheduled group execution on the fan-out backend: batched
    /// dispatches fuse their compatible sub-batch groups into one barrier
    /// schedule ([`ShardedAnalogOde::solve_groups_into`]). No-op for
    /// unsharded backends.
    pub fn set_coschedule(&mut self, on: bool) {
        if let L96Backend::AnalogSharded(ode) = &mut self.backend {
            ode.set_coschedule(on);
        }
    }

    /// Return a response's trajectory buffers to the twin's pool (see
    /// [`crate::twin::hp::HpTwin::recycle`]; ensemble responses hand back
    /// every stats trajectory plus the emptied container shell).
    pub fn recycle(&mut self, mut resp: TwinResponse) {
        if let Some(mut ens) = resp.ensemble.take() {
            ens.reclaim(&mut self.scratch.pool);
            self.scratch.ens_shells.push(ens);
        }
        self.scratch.pool.put(resp.trajectory);
    }

    /// Roll out the twin from `h0` for `n_points` samples. Noise draws
    /// come from the next auto-derived lane; use [`Twin::run`] with a
    /// seeded request for replayable rollouts.
    pub fn simulate(
        &mut self,
        h0: &[f64],
        n_points: usize,
    ) -> Result<Trajectory> {
        let mut lane = NoiseLane::from_seed(self.seeds.next_seed());
        self.simulate_lane(h0, n_points, &mut lane)
    }

    /// [`Lorenz96Twin::simulate`] drawing noise from an explicit
    /// trajectory lane — the replayable request path.
    fn simulate_lane(
        &mut self,
        h0: &[f64],
        n_points: usize,
        lane: &mut NoiseLane,
    ) -> Result<Trajectory> {
        let dt = self.dt;
        match &mut self.backend {
            L96Backend::Analog(ode) => {
                let mut out = Trajectory::new(self.dim);
                ode.solve_into(
                    h0,
                    &mut |_t, _x: &mut [f64]| {},
                    dt,
                    n_points,
                    lane,
                    &mut out,
                );
                Ok(out)
            }
            L96Backend::AnalogSharded(ode) => {
                let mut out = Trajectory::new(self.dim);
                ode.solve_into(h0, dt, n_points, lane, &mut out);
                Ok(out)
            }
            L96Backend::Digital(mlp) => {
                let mut field =
                    MlpField { mlp, label: "lorenz96/digital" };
                Ok(rk4::solve(
                    &mut field,
                    h0,
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                ))
            }
            L96Backend::Recurrent(cell) => {
                Ok(Trajectory::from_nested(&cell.rollout(h0, n_points)))
            }
            L96Backend::Pjrt(rollout) => {
                Ok(Trajectory::from_nested(&rollout(h0, None)?))
            }
        }
    }

    /// Batched rollout of one compatible sub-batch into `out` (flat rows
    /// of width `batch * dim`; shared `n_points`, per-trajectory initial
    /// states stacked in `h0s`). Analog and Digital backends are
    /// allocation-free with warm scratch — one multi-vector device read /
    /// per-layer GEMM per step for the whole batch; Recurrent runs its
    /// true batched rollout with staging allocations. Per-trajectory
    /// noise lanes ⇒ bit-identical to serial, noise on or off. Pjrt is
    /// handled by the caller's serial fallback.
    fn simulate_batch_flat(
        &mut self,
        h0s: &[f64],
        batch: usize,
        n_points: usize,
        solver: &mut L96SolverScratch,
        lanes: &mut [NoiseLane],
        out: &mut Trajectory,
    ) -> Result<()> {
        let dim = self.dim;
        debug_assert_eq!(h0s.len(), batch * dim);
        let dt = self.dt;
        match &mut self.backend {
            L96Backend::Analog(ode) => {
                ode.solve_batch_into(
                    h0s,
                    batch,
                    &mut |_b, _t, _x: &mut [f64]| {},
                    dt,
                    n_points,
                    lanes,
                    out,
                );
                Ok(())
            }
            L96Backend::AnalogSharded(ode) => {
                ode.solve_batch_into(h0s, batch, dt, n_points, lanes, out);
                Ok(())
            }
            L96Backend::Digital(mlp) => {
                let mut field = BatchMlpField {
                    mlp,
                    batch,
                    label: "lorenz96/digital",
                };
                rk4::solve_batch_into(
                    &mut field,
                    h0s,
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                    &mut solver.rk4,
                    out,
                );
                Ok(())
            }
            L96Backend::Recurrent(cell) => {
                let h0_nested: Vec<Vec<f64>> = (0..batch)
                    .map(|b| h0s[b * dim..(b + 1) * dim].to_vec())
                    .collect();
                let trajs = cell.rollout_batch(&h0_nested, n_points);
                out.reset(batch * dim);
                out.reserve_rows(n_points.max(1));
                for k in 0..trajs.first().map_or(0, Vec::len) {
                    out.push_row_from_iter(
                        (0..batch).flat_map(|b| {
                            trajs[b][k].iter().copied()
                        }),
                    );
                }
                Ok(())
            }
            L96Backend::Pjrt(_) => {
                unreachable!("pjrt uses the serial fallback")
            }
        }
    }

    /// Co-scheduled batched execution for the fan-out backend: stage
    /// *every* compatible sub-batch group first, then run them all through
    /// one fused fan-out ([`ShardedAnalogOde::solve_groups_into`]) instead
    /// of one thread scope (and one barrier schedule) per group. Request
    /// validation, seed-resolution order, lane derivation and response
    /// assembly match `run_batch_into` exactly, so responses are
    /// bit-identical with the toggle on or off. Staging is per-group owned
    /// storage — the co-scheduled path sits outside the zero-allocation
    /// contract, like the fan-out itself.
    fn run_batch_coscheduled(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        struct Stage {
            members: Vec<usize>,
            lane_base: Vec<usize>,
            h0s: Vec<f64>,
            seeds: Vec<u64>,
            lanes: Vec<NoiseLane>,
            n_points: usize,
            flat: Trajectory,
        }
        let backend = self.backend.label();
        let dim = self.dim;
        let dt = self.dt;
        let mut sc = std::mem::take(&mut self.scratch);
        sc.plan.plan_lanes(reqs, MAX_SUB_BATCH_LANES);
        sc.slots.clear();
        sc.slots.resize_with(reqs.len(), || None);
        let mut stages: Vec<Stage> = Vec::new();
        for g in 0..sc.plan.n_groups() {
            let n_points = reqs[sc.plan.group(g)[0]].n_points;
            let mut st = Stage {
                members: Vec::new(),
                lane_base: Vec::new(),
                h0s: Vec::new(),
                seeds: Vec::new(),
                lanes: Vec::new(),
                n_points,
                flat: Trajectory::new(dim),
            };
            let mut lane_count = 0;
            for &i in sc.plan.group(g) {
                let h0: &[f64] = if reqs[i].h0.is_empty() {
                    &self.default_h0
                } else {
                    &reqs[i].h0
                };
                if h0.len() != dim {
                    sc.slots[i] = Some(Err(anyhow::anyhow!(
                        "h0 dim {} != twin dim {}",
                        h0.len(),
                        dim
                    )));
                    continue;
                }
                if let Some(spec) = &reqs[i].ensemble {
                    if let Err(e) = spec.validate() {
                        sc.slots[i] = Some(Err(e));
                        continue;
                    }
                }
                st.members.push(i);
                st.lane_base.push(lane_count);
                for _ in 0..reqs[i].lanes() {
                    st.h0s.extend_from_slice(h0);
                }
                lane_count += reqs[i].lanes();
            }
            // Seeds and lanes in a second pass: the sequencer lives on
            // `self`, which the default-h0 borrow above keeps off-limits.
            for &i in &st.members {
                let seed = self.seeds.resolve(reqs[i].seed);
                st.seeds.push(seed);
                if reqs[i].ensemble.is_some() {
                    for m in 0..reqs[i].lanes() {
                        st.lanes.push(NoiseLane::from_seed(
                            ensemble_member_seed(seed, m as u64),
                        ));
                    }
                } else {
                    st.lanes.push(NoiseLane::from_seed(seed));
                }
            }
            if !st.members.is_empty() {
                stages.push(st);
            }
        }
        match &mut self.backend {
            L96Backend::AnalogSharded(ode) => {
                let mut groups: Vec<ShardGroup<'_>> = stages
                    .iter_mut()
                    .map(|st| ShardGroup {
                        h0s: &st.h0s,
                        batch: st.lanes.len(),
                        dt_out: dt,
                        n_points: st.n_points,
                        lanes: &mut st.lanes,
                        out: &mut st.flat,
                    })
                    .collect();
                ode.solve_groups_into(&mut groups);
            }
            _ => unreachable!(
                "co-scheduled path requires the sharded backend"
            ),
        }
        for st in &stages {
            let batch = st.lanes.len();
            for (k, &i) in st.members.iter().enumerate() {
                let base = st.lane_base[k];
                match &reqs[i].ensemble {
                    None => {
                        let mut t = sc.pool.get(dim);
                        unbatch_into(&st.flat, batch, dim, base, &mut t);
                        sc.slots[i] = Some(Ok(TwinResponse {
                            trajectory: t,
                            backend,
                            seed: st.seeds[k],
                            ensemble: None,
                            degraded: false,
                        }));
                    }
                    Some(spec) => {
                        let shell =
                            sc.ens_shells.pop().unwrap_or_default();
                        let (t, stats) = assemble_ensemble_stats(
                            spec,
                            &st.flat,
                            crate::twin::EnsembleSlot { batch, dim, base },
                            &mut sc.acc,
                            &mut sc.pool,
                            shell,
                        );
                        sc.slots[i] = Some(Ok(TwinResponse {
                            trajectory: t,
                            backend,
                            seed: st.seeds[k],
                            ensemble: Some(stats),
                            degraded: false,
                        }));
                    }
                }
            }
        }
        for s in sc.slots.drain(..) {
            out.push(s.expect("every request receives a result"));
        }
        self.scratch = sc;
    }
}

impl Twin for Lorenz96Twin {
    fn name(&self) -> &str {
        "lorenz96"
    }

    fn state_dim(&self) -> usize {
        self.dim
    }

    fn dt(&self) -> f64 {
        self.dt
    }

    fn default_h0(&self) -> Vec<f64> {
        self.default_h0.clone()
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        if req.ensemble.is_some() {
            // Ensembles always execute as one batched rollout, even when
            // submitted serially (one request = one sub-batch of N lanes).
            let mut out = Vec::with_capacity(1);
            self.run_batch_into(std::slice::from_ref(req), &mut out);
            return out.pop().expect("one result per request");
        }
        // The default-h0 copy keeps `self` free for the mutable simulate
        // call below; the batched path stages initial states without it.
        let default_h0;
        let h0: &[f64] = if req.h0.is_empty() {
            default_h0 = self.default_h0.clone();
            &default_h0
        } else {
            &req.h0
        };
        anyhow::ensure!(
            h0.len() == self.dim,
            "h0 dim {} != twin dim {}",
            h0.len(),
            self.dim
        );
        let backend = self.backend.label();
        let seed = self.seeds.resolve(req.seed);
        let mut lane = NoiseLane::from_seed(seed);
        let trajectory = self.simulate_lane(h0, req.n_points, &mut lane)?;
        Ok(TwinResponse {
            trajectory,
            backend,
            seed,
            ensemble: None,
            degraded: false,
        })
    }

    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        let mut out = Vec::with_capacity(reqs.len());
        self.run_batch_into(reqs, &mut out);
        out
    }

    /// Batched execution: requests split into compatible sub-batches (same
    /// `n_points`, lane-counted capacity); initial states are resolved per
    /// request, and a request with the wrong h0 dimension (or an invalid
    /// ensemble spec) fails alone without poisoning the rest. An ensemble
    /// request expands into `EnsembleSpec::members` noise lanes (member
    /// `k` seeded by [`ensemble_member_seed`]) inside the group's single
    /// batched rollout — including the tile-sharded execution forms — and
    /// its response carries pooled [`EnsembleStats`].
    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        if let L96Backend::AnalogSharded(ode) = &self.backend {
            if ode.coschedule() {
                return self.run_batch_coscheduled(reqs, out);
            }
        }
        let backend = self.backend.label();
        let dim = self.dim;
        let mut sc = std::mem::take(&mut self.scratch);
        sc.plan.plan_lanes(reqs, MAX_SUB_BATCH_LANES);
        sc.slots.clear();
        sc.slots.resize_with(reqs.len(), || None);
        for g in 0..sc.plan.n_groups() {
            let n_points = reqs[sc.plan.group(g)[0]].n_points;
            sc.members.clear();
            sc.lane_base.clear();
            sc.h0s.clear();
            sc.seeds.clear();
            sc.lanes.clear();
            let mut lane_count = 0;
            for &i in sc.plan.group(g) {
                let h0: &[f64] = if reqs[i].h0.is_empty() {
                    &self.default_h0
                } else {
                    &reqs[i].h0
                };
                if h0.len() != dim {
                    sc.slots[i] = Some(Err(anyhow::anyhow!(
                        "h0 dim {} != twin dim {}",
                        h0.len(),
                        dim
                    )));
                    continue;
                }
                if let Some(spec) = &reqs[i].ensemble {
                    if let Err(e) = spec.validate() {
                        sc.slots[i] = Some(Err(e));
                        continue;
                    }
                }
                sc.members.push(i);
                sc.lane_base.push(lane_count);
                for _ in 0..reqs[i].lanes() {
                    sc.h0s.extend_from_slice(h0);
                }
                lane_count += reqs[i].lanes();
            }
            // Seeds and lanes in a second pass: the sequencer lives on
            // `self`, which the default-h0 borrow above keeps off-limits.
            for &i in &sc.members {
                let seed = self.seeds.resolve(reqs[i].seed);
                sc.seeds.push(seed);
                if reqs[i].ensemble.is_some() {
                    for m in 0..reqs[i].lanes() {
                        sc.lanes.push(NoiseLane::from_seed(
                            ensemble_member_seed(seed, m as u64),
                        ));
                    }
                } else {
                    sc.lanes.push(NoiseLane::from_seed(seed));
                }
            }
            if sc.members.is_empty() {
                continue;
            }
            let batch = sc.lanes.len();
            if matches!(self.backend, L96Backend::Pjrt(_)) {
                // No batched artifact path yet: per-trajectory rollouts
                // (and therefore no single-rollout ensemble expansion).
                for k in 0..sc.members.len() {
                    let i = sc.members[k];
                    if reqs[i].ensemble.is_some() {
                        sc.slots[i] = Some(Err(anyhow::anyhow!(
                            "ensemble requests are not supported on the \
                             pjrt backend"
                        )));
                        continue;
                    }
                    let base = sc.lane_base[k];
                    let seed = sc.seeds[k];
                    let r = self
                        .simulate_lane(
                            &sc.h0s[base * dim..(base + 1) * dim],
                            n_points,
                            &mut sc.lanes[base],
                        )
                        .map(|trajectory| TwinResponse {
                            trajectory,
                            backend,
                            seed,
                            ensemble: None,
                            degraded: false,
                        });
                    sc.slots[i] = Some(r);
                }
                continue;
            }
            match self.simulate_batch_flat(
                &sc.h0s,
                batch,
                n_points,
                &mut sc.solver,
                &mut sc.lanes,
                &mut sc.flat,
            ) {
                Ok(()) => {
                    for (k, &i) in sc.members.iter().enumerate() {
                        let base = sc.lane_base[k];
                        match &reqs[i].ensemble {
                            None => {
                                let mut t = sc.pool.get(dim);
                                unbatch_into(
                                    &sc.flat, batch, dim, base, &mut t,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: None,
                                    degraded: false,
                                }));
                            }
                            Some(spec) => {
                                let shell = sc
                                    .ens_shells
                                    .pop()
                                    .unwrap_or_default();
                                let (t, stats) = assemble_ensemble_stats(
                                    spec,
                                    &sc.flat,
                                    crate::twin::EnsembleSlot {
                                        batch,
                                        dim,
                                        base,
                                    },
                                    &mut sc.acc,
                                    &mut sc.pool,
                                    shell,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: Some(stats),
                                    degraded: false,
                                }));
                            }
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &i in &sc.members {
                        sc.slots[i] =
                            Some(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
        }
        for s in sc.slots.drain(..) {
            out.push(s.expect("every request receives a result"));
        }
        self.scratch = sc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Mat;

    /// f(h) = -h element-wise (the shared exact-ReLU decay fixture).
    fn toy_weights(d: usize) -> MlpWeights {
        crate::models::loader::decay_mlp_weights(d)
    }

    #[test]
    fn digital_twin_decays_componentwise() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        let traj = twin.simulate(&[1.0, -2.0, 0.5], 51).unwrap();
        let last = traj.last().unwrap();
        let decay = (-1.0f64).exp();
        assert!((last[0] - decay).abs() < 1e-4);
        assert!((last[1] + 2.0 * decay).abs() < 1e-4);
        assert!((last[2] - 0.5 * decay).abs() < 1e-4);
    }

    #[test]
    fn analog_matches_digital_noise_free() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut ana = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut dig = Lorenz96Twin::digital(&w);
        let a = ana.simulate(&[1.0, 0.5, -0.5], 50).unwrap();
        let d = dig.simulate(&[1.0, 0.5, -0.5], 50).unwrap();
        let err = crate::metrics::l1::mean_l1_multi(
            &a.to_nested(),
            &d.to_nested(),
        );
        assert!(err < 0.01, "analog vs digital L1 {err}");
    }

    #[test]
    fn aging_twin_matches_plain_at_age_zero_then_drifts_and_recals() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let h0 = [1.0, 0.5, -0.5];
        let mut plain = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut aging = Lorenz96Twin::analog_aging(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            ANALOG_SUBSTEPS,
        );
        assert!(aging.is_aging() && !plain.is_aging());
        let fresh = aging.simulate(&h0, 20).unwrap();
        assert_eq!(
            fresh,
            plain.simulate(&h0, 20).unwrap(),
            "aging deployment diverged from plain at age 0"
        );
        aging.advance_age(1e7);
        assert_eq!(aging.age_s(), 1e7);
        let aged = aging.simulate(&h0, 20).unwrap();
        let dev = |a: &Trajectory, b: &Trajectory| {
            crate::metrics::l1::mean_l1_multi(
                &a.to_nested(),
                &b.to_nested(),
            )
        };
        assert!(dev(&aged, &fresh) > 0.0, "aging left the rollout intact");
        let pulses = aging.recalibrate();
        assert!(pulses > 0);
        assert_eq!(aging.recalibrations(), 1);
        assert_eq!(aging.lifetime_pulses(), pulses);
        let recal = aging.simulate(&h0, 20).unwrap();
        assert!(
            dev(&recal, &fresh) < dev(&aged, &fresh),
            "recalibration did not move the rollout back"
        );
        assert_eq!(aging.array_health(), 1.0);
    }

    #[test]
    fn twin_trait_uses_default_h0() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 5)).unwrap();
        assert_eq!(resp.trajectory.row(0), &lorenz96::Y0[..]);
    }

    #[test]
    fn wrong_h0_dim_rejected() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let req = TwinRequest::autonomous(vec![1.0, 2.0], 5);
        assert!(twin.run(&req).is_err());
    }

    #[test]
    fn recurrent_backend_from_weights() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::zeros(3, 4),
            wh: Mat::zeros(4, 4),
            b: vec![0.0; 4],
            wo: Mat::zeros(4, 3),
            bo: vec![0.0; 3],
            hidden: 4,
            d_in: 3,
            dt: 0.02,
            kind: "rnn".into(),
        };
        let mut twin = Lorenz96Twin::recurrent(&w).unwrap();
        let traj = twin.simulate(&[1.0, 2.0, 3.0], 4).unwrap();
        assert_eq!(traj.len(), 4);
        // Zero weights: identity rollout.
        assert_eq!(traj.row(3), [1.0, 2.0, 3.0]);
    }

    /// Mixed n_points, explicit dim-3 initial states (the empty-h0 default
    /// case is covered separately by `default_h0_resolved_in_batch`).
    fn mixed_requests() -> Vec<TwinRequest> {
        vec![
            TwinRequest::autonomous(vec![1.0, -2.0, 0.5], 30),
            TwinRequest::autonomous(vec![0.2, 0.1, -0.4], 12),
            TwinRequest::autonomous(vec![0.6, -0.1, 0.3], 30),
            TwinRequest::autonomous(vec![-1.0, 1.0, 0.0], 30),
        ]
    }

    fn assert_batch_matches_serial(twin: &mut Lorenz96Twin) {
        let reqs = mixed_requests();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
            assert_eq!(b.backend, s.backend);
        }
        // Warm-scratch pass with recycling: pooled buffers must not leak
        // stale samples between batches.
        for (resp, s) in twin.run_batch(&reqs).into_iter().zip(&serial) {
            let resp = resp.unwrap();
            assert_eq!(resp.trajectory, s.trajectory);
            twin.recycle(resp);
        }
        let third = twin.run_batch(&reqs);
        for (b, s) in third.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap().trajectory, s.trajectory);
        }
    }

    #[test]
    fn digital_run_batch_bit_identical_to_serial() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn analog_run_batch_bit_identical_to_serial_noise_free() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin =
            Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn sharded_serial_twin_bit_identical_to_monolithic() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut mono = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut sharded = Lorenz96Twin::analog_opts(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            L96AnalogOpts { shards: 2, ..Default::default() },
        );
        assert_eq!(sharded.backend.label(), "analog");
        let reqs = mixed_requests();
        let a = mono.run_batch(&reqs);
        let b = sharded.run_batch(&reqs);
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.as_ref().unwrap().trajectory,
                y.as_ref().unwrap().trajectory,
                "request {k}"
            );
        }
    }

    #[test]
    fn sharded_parallel_twin_reports_backend_and_telemetry() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin = Lorenz96Twin::analog_opts(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            L96AnalogOpts { shards: 2, parallel: true, ..Default::default() },
        );
        let resp =
            twin.run(&TwinRequest::autonomous(vec![0.5, -0.25, 0.1], 4));
        let resp = resp.unwrap();
        assert_eq!(resp.backend, "analog-sharded");
        assert_eq!(resp.trajectory.len(), 4);
        let tel = twin.shard_telemetry().expect("sharded backend");
        assert_eq!(tel.len(), 2);
        assert!(tel.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn seeded_noisy_rollouts_identical_across_execution_forms() {
        // One seed, three execution forms (monolithic, serial sharded,
        // parallel fan-out), serial and batched dispatch: every noisy
        // trajectory must be bit-identical to the monolithic serial one.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = |shards, parallel| L96AnalogOpts {
            substeps: 2,
            shards,
            parallel,
        };
        let mut mono =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        let reqs: Vec<TwinRequest> = (0..3)
            .map(|k| {
                TwinRequest::autonomous(
                    (0..d)
                        .map(|i| ((i + k) as f64 * 0.21).sin() * 0.5)
                        .collect(),
                    4,
                )
                .with_seed(900 + k as u64)
            })
            .collect();
        let want: Vec<_> =
            reqs.iter().map(|r| mono.run(r).unwrap()).collect();
        for (label, mut twin) in [
            (
                "monolithic",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false)),
            ),
            (
                "serial sharded",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, false)),
            ),
            (
                "parallel fan-out",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, true)),
            ),
        ] {
            let serial: Vec<_> =
                reqs.iter().map(|r| twin.run(r).unwrap()).collect();
            let batched = twin.run_batch(&reqs);
            for (k, w0) in want.iter().enumerate() {
                assert_eq!(
                    serial[k].trajectory, w0.trajectory,
                    "{label}: serial request {k} diverged"
                );
                assert_eq!(
                    batched[k].as_ref().unwrap().trajectory,
                    w0.trajectory,
                    "{label}: batched request {k} diverged"
                );
                assert_eq!(batched[k].as_ref().unwrap().seed, 900 + k as u64);
            }
        }
    }

    #[test]
    fn ensemble_identical_across_execution_forms() {
        use crate::twin::{ensemble_member_seed, EnsembleSpec};
        // One seed, 8 members, three execution forms: member k equals a
        // standalone rollout seeded with ensemble_member_seed(seed, k),
        // and the pooled stats are identical everywhere.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = |shards, parallel| L96AnalogOpts {
            substeps: 2,
            shards,
            parallel,
        };
        let h0: Vec<f64> =
            (0..d).map(|i| (i as f64 * 0.17).sin() * 0.5).collect();
        let n = 8;
        let req = TwinRequest::autonomous(h0.clone(), 4)
            .with_seed(4242)
            .with_ensemble(
                EnsembleSpec::new(n)
                    .with_percentiles(vec![5.0, 95.0])
                    .with_member_trajectories(),
            );
        let mut reference =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        let want = reference.run(&req).unwrap();
        let want_ens = want.ensemble.as_ref().unwrap();
        assert_eq!(want_ens.members, n);
        // Member k == standalone derived-seed rollout on a fresh twin.
        let mut fresh =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        for (k, member) in
            want_ens.member_trajectories.iter().enumerate()
        {
            let standalone = fresh
                .run(
                    &TwinRequest::autonomous(h0.clone(), 4)
                        .with_seed(ensemble_member_seed(4242, k as u64)),
                )
                .unwrap();
            assert_eq!(
                *member, standalone.trajectory,
                "member {k} != standalone derived-seed rollout"
            );
        }
        for (label, mut twin) in [
            (
                "serial sharded",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, false)),
            ),
            (
                "parallel fan-out",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, true)),
            ),
        ] {
            let got = twin.run(&req).unwrap();
            let ens = got.ensemble.as_ref().unwrap();
            assert_eq!(
                got.trajectory, want.trajectory,
                "{label}: ensemble mean diverged"
            );
            assert_eq!(ens.mean, want_ens.mean, "{label}: mean");
            assert_eq!(ens.std, want_ens.std, "{label}: std");
            assert_eq!(
                ens.percentiles, want_ens.percentiles,
                "{label}: percentiles"
            );
            assert_eq!(
                ens.member_trajectories, want_ens.member_trajectories,
                "{label}: members"
            );
        }
    }

    #[test]
    fn coscheduled_batch_bit_identical_to_per_group_fanout() {
        use crate::twin::EnsembleSpec;
        // A mixed seeded batch that splits into several compatible groups
        // (two n_points values, one ensemble expansion): co-scheduling
        // fuses the groups into one barrier schedule and must not change
        // one output byte — noise on, lane cursors and stats included.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = L96AnalogOpts {
            substeps: 2,
            shards: 2,
            parallel: true,
        };
        let h0 = |k: usize| -> Vec<f64> {
            (0..d).map(|i| ((i + k) as f64 * 0.13).sin() * 0.4).collect()
        };
        let reqs = vec![
            TwinRequest::autonomous(h0(0), 4).with_seed(11),
            TwinRequest::autonomous(h0(1), 6).with_seed(12),
            TwinRequest::autonomous(h0(2), 4)
                .with_seed(13)
                .with_ensemble(
                    EnsembleSpec::new(3).with_percentiles(vec![10.0, 90.0]),
                ),
            TwinRequest::autonomous(h0(3), 6).with_seed(14),
        ];
        let mut plain = Lorenz96Twin::analog_opts(
            &w, &cfg, noise, 5, opts.clone(),
        );
        let want = plain.run_batch(&reqs);
        let mut fused =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts);
        fused.set_coschedule(true);
        let got = fused.run_batch(&reqs);
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            let a = a.as_ref().unwrap();
            let b = b.as_ref().unwrap();
            assert_eq!(
                a.trajectory, b.trajectory,
                "request {k} diverged under co-scheduling"
            );
            assert_eq!(a.seed, b.seed, "request {k} seed");
            match (&a.ensemble, &b.ensemble) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.mean, y.mean, "request {k} mean");
                    assert_eq!(x.std, y.std, "request {k} std");
                    assert_eq!(
                        x.percentiles, y.percentiles,
                        "request {k} percentiles"
                    );
                }
                _ => panic!("request {k}: ensemble presence diverged"),
            }
        }
        // A bad request still fails alone on the co-scheduled path.
        let mixed = vec![
            TwinRequest::autonomous(h0(0), 4).with_seed(21),
            TwinRequest::autonomous(vec![0.0; 3], 4).with_seed(22),
        ];
        let res = fused.run_batch(&mixed);
        assert!(res[0].is_ok());
        assert!(res[1].is_err(), "bad h0 dim must fail alone");
    }

    #[test]
    fn default_h0_resolved_in_batch() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let results = twin.run_batch(&[
            TwinRequest::autonomous(vec![], 5),
            TwinRequest::autonomous(vec![0.5; 6], 5),
        ]);
        assert_eq!(
            results[0].as_ref().unwrap().trajectory.row(0),
            &lorenz96::Y0[..]
        );
        assert_eq!(
            results[1].as_ref().unwrap().trajectory.row(0),
            [0.5; 6]
        );
    }

    #[test]
    fn run_batch_isolates_bad_h0_dim() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        let results = twin.run_batch(&[
            TwinRequest::autonomous(vec![1.0, 2.0, 3.0], 8),
            TwinRequest::autonomous(vec![1.0, 2.0], 8),
            TwinRequest::autonomous(vec![0.0, 0.5, -0.5], 8),
        ]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
    }

    #[test]
    fn recurrent_run_batch_matches_serial() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::from_fn(3, 4, |r, c| 0.05 * ((r + c) % 3) as f64),
            wh: Mat::from_fn(4, 4, |r, c| 0.03 * ((r * 2 + c) % 5) as f64),
            b: vec![0.01; 4],
            wo: Mat::from_fn(4, 3, |r, c| 0.1 * ((r + c) % 2) as f64),
            bo: vec![0.0; 3],
            hidden: 4,
            d_in: 3,
            dt: 0.02,
            kind: "rnn".into(),
        };
        let mut twin = Lorenz96Twin::recurrent(&w).unwrap();
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn unknown_recurrent_kind_errors() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::zeros(1, 1),
            wh: Mat::zeros(1, 1),
            b: vec![0.0],
            wo: Mat::zeros(1, 1),
            bo: vec![0.0],
            hidden: 1,
            d_in: 1,
            dt: 0.02,
            kind: "transformer".into(),
        };
        assert!(Lorenz96Twin::recurrent(&w).is_err());
    }
}
