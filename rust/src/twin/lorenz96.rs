//! The Lorenz96 digital twin (Fig. 4): an autonomously evolving
//! six-dimensional atmospheric model.
//!
//! Backends: analogue solver, Rust RK4, the recurrent baselines
//! (RNN/GRU/LSTM, Fig. 4g-i), or the AOT PJRT artifact.
//!
//! Since the generic-core refactor this type is thin configuration over
//! [`DynamicsTwin`]: every constructor builds a [`TwinSpec`] (autonomous,
//! dimension from the weights, `lorenz96::default_y0` initial condition)
//! plus a [`CoreBackend`], and all request execution — batching,
//! grouping, seed stamping, ensemble expansion, sharded/co-scheduled
//! dispatch, pooled responses — happens on the shared core path that
//! `twin/core.rs` enforces the invariants on.

use anyhow::Result;

use crate::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use crate::device::taox::DeviceConfig;
use crate::models::gru::Gru;
use crate::models::loader::{MlpWeights, RnnWeights};
use crate::models::lstm::Lstm;
use crate::models::mlp::Mlp;
use crate::models::rnn::{Recurrent, VanillaRnn};
use crate::twin::core::{
    CoreBackend, DigitalModel, DynamicsTwin, StimulusKind, TwinSpec,
};
use crate::twin::shard::{ShardExecutor, ShardSnapshot, ShardedAnalogOde};
use crate::twin::{RolloutFn, Twin, TwinRequest, TwinResponse};
use crate::util::tensor::Trajectory;
use crate::workload::lorenz96;

/// Default circuit substeps per output sample for the analogue backend.
pub const ANALOG_SUBSTEPS: usize = 20;
/// RK4 substeps per output sample for the digital backend.
pub const DIGITAL_SUBSTEPS: usize = 1;

/// Auto-seed root for backends built without an explicit seed (digital,
/// recurrent, pjrt — the seed is still resolved and echoed for replay).
const L96_AUTO_ROOT: u64 = 0x1963_5eed_0000_0002;

/// Analogue-backend options: circuit substeps plus the tile-shard layout.
#[derive(Debug, Clone)]
pub struct L96AnalogOpts {
    /// Circuit substeps per output sample.
    pub substeps: usize,
    /// Shard count; 0 or 1 keeps the monolithic kernel.
    pub shards: usize,
    /// Fan shards out across parallel shard workers
    /// ([`ShardedAnalogOde`]); `false` runs the serial sharded kernel
    /// inside [`AnalogNeuralOde`] (zero-allocation warm path).
    pub parallel: bool,
}

impl Default for L96AnalogOpts {
    fn default() -> Self {
        Self { substeps: ANALOG_SUBSTEPS, shards: 1, parallel: false }
    }
}

/// The Lorenz96 twin: configuration of the generic [`DynamicsTwin`] core.
pub struct Lorenz96Twin {
    core: DynamicsTwin,
}

impl Lorenz96Twin {
    fn spec(dim: usize, dt: f64) -> TwinSpec {
        TwinSpec {
            name: "lorenz96",
            field_label: "lorenz96/digital",
            dim,
            dt,
            default_h0: lorenz96::default_y0(dim),
            stimulus: StimulusKind::Autonomous,
            digital_substeps: DIGITAL_SUBSTEPS,
        }
    }

    fn assemble(
        backend: CoreBackend,
        dt: f64,
        dim: usize,
        lane_root: u64,
    ) -> Self {
        Self {
            core: DynamicsTwin::new(
                Self::spec(dim, dt),
                backend,
                lane_root,
            ),
        }
    }

    /// Analogue-backend twin from trained weights (monolithic kernel,
    /// paper-default substeps).
    pub fn analog(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        Self::analog_opts(weights, cfg, noise, seed, L96AnalogOpts::default())
    }

    /// Analogue-backend twin with explicit substeps and tile-shard layout.
    /// `opts.shards > 1` splits states wider than one physical array
    /// across tile column-groups; with `opts.parallel` the shards execute
    /// on parallel shard workers, otherwise serially in the solver. Both
    /// sharded forms are bit-identical to the monolithic kernel under
    /// noise-off deployment (asserted in `rust/tests/sharded.rs`).
    pub fn analog_opts(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        opts: L96AnalogOpts,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let dim = weights.layers.last().unwrap().0.cols;
        let mlp = AnalogMlp::deploy(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let substeps = opts.substeps.max(1);
        let ode = AnalogNeuralOde::new(mlp, dim, dt / substeps as f64);
        let backend = if opts.shards > 1 && opts.parallel {
            let sharded = ShardedAnalogOde::from_ode(
                &ode,
                ShardExecutor::new(opts.shards),
            );
            CoreBackend::AnalogSharded(Box::new(sharded))
        } else if opts.shards > 1 {
            CoreBackend::Analog(Box::new(ode.with_shards(opts.shards)))
        } else {
            CoreBackend::Analog(Box::new(ode))
        };
        Self::assemble(backend, dt, dim, seed)
    }

    /// Analogue-backend twin on *mortal* hardware: deployed via
    /// [`AnalogMlp::deploy_aging`], so the crossbars keep their physical
    /// state and expose the virtual-clock lifetime API
    /// ([`Lorenz96Twin::advance_age`], [`Lorenz96Twin::recalibrate`], …).
    /// Monolithic kernel only — aging engines refresh in place, which the
    /// tile-shard execution forms do not support. At age 0 this twin is
    /// bit-identical to [`Lorenz96Twin::analog`] under the same seed.
    pub fn analog_aging(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let dim = weights.layers.last().unwrap().0.cols;
        let mlp = AnalogMlp::deploy_aging(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let substeps = substeps.max(1);
        let ode = AnalogNeuralOde::new(mlp, dim, dt / substeps as f64);
        Self::assemble(CoreBackend::Analog(Box::new(ode)), dt, dim, seed)
    }

    /// Whether this twin runs on mortal (aging) analogue hardware.
    pub fn is_aging(&self) -> bool {
        self.core.is_aging()
    }

    /// Advance the hardware's virtual clock by `dt_s` seconds (drift +
    /// diffusion on every cell, engines refreshed). No-op for `dt_s <= 0`;
    /// panics on a non-aging twin.
    pub fn advance_age(&mut self, dt_s: f64) {
        self.core.advance_age(dt_s);
    }

    /// Reprogram every array back to its target weights; returns the
    /// write-verify pulse count (energy via
    /// [`crate::energy::recalibration_energy`]).
    pub fn recalibrate(&mut self) -> u64 {
        self.core.recalibrate()
    }

    /// Virtual device age (s); 0 for immortal twins.
    pub fn age_s(&self) -> f64 {
        self.core.age_s()
    }

    /// Healthy-cell fraction across every deployed array (1.0 if
    /// immortal).
    pub fn array_health(&self) -> f64 {
        self.core.array_health()
    }

    /// Lifetime write-verify pulses spent on recalibration.
    pub fn lifetime_pulses(&self) -> u64 {
        self.core.lifetime_pulses()
    }

    /// Completed recalibration count.
    pub fn recalibrations(&self) -> u64 {
        self.core.recalibrations()
    }

    /// Mark a random `fraction` of cells stuck (fault-injection campaigns;
    /// deterministic in the deployment's aging stream). Panics on a
    /// non-aging twin.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        self.core.inject_stuck_faults(fraction);
    }

    /// Digital (Rust RK4) twin.
    pub fn digital(weights: &MlpWeights) -> Self {
        let dim = weights.layers.last().unwrap().0.cols;
        Self::assemble(
            CoreBackend::Digital(DigitalModel::Mlp(Mlp::from_weights(
                weights,
            ))),
            weights.dt,
            dim,
            L96_AUTO_ROOT,
        )
    }

    /// Recurrent baseline twin ("rnn" | "gru" | "lstm").
    pub fn recurrent(weights: &RnnWeights) -> Result<Self> {
        let cell: Box<dyn Recurrent + Send> = match weights.kind.as_str() {
            "rnn" => Box::new(VanillaRnn::new(weights.clone())),
            "gru" => Box::new(Gru::new(weights.clone())),
            "lstm" => Box::new(Lstm::new(weights.clone())),
            other => anyhow::bail!("unknown recurrent kind '{other}'"),
        };
        Ok(Self::assemble(
            CoreBackend::Recurrent(cell),
            weights.dt,
            weights.d_in,
            L96_AUTO_ROOT,
        ))
    }

    /// PJRT-artifact twin.
    pub fn pjrt(rollout: RolloutFn, dt: f64, dim: usize) -> Self {
        Self::assemble(CoreBackend::Pjrt(rollout), dt, dim, L96_AUTO_ROOT)
    }

    /// Unwrap into the generic core (health monitoring composes twins at
    /// the core layer).
    pub(crate) fn into_core(self) -> DynamicsTwin {
        self.core
    }

    /// Per-shard serving counters of the fan-out backend, if sharded.
    pub fn shard_telemetry(&self) -> Option<Vec<ShardSnapshot>> {
        self.core.shard_telemetry()
    }

    /// Wire the fan-out backend's rollout counters into the coordinator's
    /// serving telemetry (no-op for unsharded backends).
    pub fn attach_coordinator_telemetry(
        &mut self,
        t: std::sync::Arc<crate::coordinator::telemetry::Telemetry>,
    ) {
        self.core.attach_coordinator_telemetry(t);
    }

    /// Toggle co-scheduled group execution on the fan-out backend: batched
    /// dispatches fuse their compatible sub-batch groups into one barrier
    /// schedule ([`ShardedAnalogOde::solve_groups_into`]). No-op for
    /// unsharded backends.
    pub fn set_coschedule(&mut self, on: bool) {
        self.core.set_coschedule(on);
    }

    /// Return a response's trajectory buffers to the twin's pool (see
    /// [`crate::twin::hp::HpTwin::recycle`]; ensemble responses hand back
    /// every stats trajectory plus the emptied container shell).
    pub fn recycle(&mut self, resp: TwinResponse) {
        self.core.recycle(resp);
    }

    /// Roll out the twin from `h0` for `n_points` samples. Noise draws
    /// come from the next auto-derived lane; use [`Twin::run`] with a
    /// seeded request for replayable rollouts.
    pub fn simulate(
        &mut self,
        h0: &[f64],
        n_points: usize,
    ) -> Result<Trajectory> {
        self.core.simulate(None, h0, n_points)
    }
}

impl Twin for Lorenz96Twin {
    fn name(&self) -> &str {
        self.core.name()
    }

    fn state_dim(&self) -> usize {
        self.core.state_dim()
    }

    fn dt(&self) -> f64 {
        self.core.dt()
    }

    fn default_h0(&self) -> Vec<f64> {
        self.core.default_h0()
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        self.core.run(req)
    }

    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        self.core.run_batch(reqs)
    }

    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        self.core.run_batch_into(reqs, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Mat;

    /// f(h) = -h element-wise (the shared exact-ReLU decay fixture).
    fn toy_weights(d: usize) -> MlpWeights {
        crate::models::loader::decay_mlp_weights(d)
    }

    #[test]
    fn digital_twin_decays_componentwise() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        let traj = twin.simulate(&[1.0, -2.0, 0.5], 51).unwrap();
        let last = traj.last().unwrap();
        let decay = (-1.0f64).exp();
        assert!((last[0] - decay).abs() < 1e-4);
        assert!((last[1] + 2.0 * decay).abs() < 1e-4);
        assert!((last[2] - 0.5 * decay).abs() < 1e-4);
    }

    #[test]
    fn analog_matches_digital_noise_free() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut ana = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut dig = Lorenz96Twin::digital(&w);
        let a = ana.simulate(&[1.0, 0.5, -0.5], 50).unwrap();
        let d = dig.simulate(&[1.0, 0.5, -0.5], 50).unwrap();
        let err = crate::metrics::l1::mean_l1_multi(
            &a.to_nested(),
            &d.to_nested(),
        );
        assert!(err < 0.01, "analog vs digital L1 {err}");
    }

    #[test]
    fn aging_twin_matches_plain_at_age_zero_then_drifts_and_recals() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let h0 = [1.0, 0.5, -0.5];
        let mut plain = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut aging = Lorenz96Twin::analog_aging(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            ANALOG_SUBSTEPS,
        );
        assert!(aging.is_aging() && !plain.is_aging());
        let fresh = aging.simulate(&h0, 20).unwrap();
        assert_eq!(
            fresh,
            plain.simulate(&h0, 20).unwrap(),
            "aging deployment diverged from plain at age 0"
        );
        aging.advance_age(1e7);
        assert_eq!(aging.age_s(), 1e7);
        let aged = aging.simulate(&h0, 20).unwrap();
        let dev = |a: &Trajectory, b: &Trajectory| {
            crate::metrics::l1::mean_l1_multi(
                &a.to_nested(),
                &b.to_nested(),
            )
        };
        assert!(dev(&aged, &fresh) > 0.0, "aging left the rollout intact");
        let pulses = aging.recalibrate();
        assert!(pulses > 0);
        assert_eq!(aging.recalibrations(), 1);
        assert_eq!(aging.lifetime_pulses(), pulses);
        let recal = aging.simulate(&h0, 20).unwrap();
        assert!(
            dev(&recal, &fresh) < dev(&aged, &fresh),
            "recalibration did not move the rollout back"
        );
        assert_eq!(aging.array_health(), 1.0);
    }

    #[test]
    fn twin_trait_uses_default_h0() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 5)).unwrap();
        assert_eq!(resp.trajectory.row(0), &lorenz96::Y0[..]);
    }

    #[test]
    fn wrong_h0_dim_rejected() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let req = TwinRequest::autonomous(vec![1.0, 2.0], 5);
        assert!(twin.run(&req).is_err());
    }

    #[test]
    fn recurrent_backend_from_weights() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::zeros(3, 4),
            wh: Mat::zeros(4, 4),
            b: vec![0.0; 4],
            wo: Mat::zeros(4, 3),
            bo: vec![0.0; 3],
            hidden: 4,
            d_in: 3,
            dt: 0.02,
            kind: "rnn".into(),
        };
        let mut twin = Lorenz96Twin::recurrent(&w).unwrap();
        let traj = twin.simulate(&[1.0, 2.0, 3.0], 4).unwrap();
        assert_eq!(traj.len(), 4);
        // Zero weights: identity rollout.
        assert_eq!(traj.row(3), [1.0, 2.0, 3.0]);
    }

    /// Mixed n_points, explicit dim-3 initial states (the empty-h0 default
    /// case is covered separately by `default_h0_resolved_in_batch`).
    fn mixed_requests() -> Vec<TwinRequest> {
        vec![
            TwinRequest::autonomous(vec![1.0, -2.0, 0.5], 30),
            TwinRequest::autonomous(vec![0.2, 0.1, -0.4], 12),
            TwinRequest::autonomous(vec![0.6, -0.1, 0.3], 30),
            TwinRequest::autonomous(vec![-1.0, 1.0, 0.0], 30),
        ]
    }

    fn assert_batch_matches_serial(twin: &mut Lorenz96Twin) {
        let reqs = mixed_requests();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
            assert_eq!(b.backend, s.backend);
        }
        // Warm-scratch pass with recycling: pooled buffers must not leak
        // stale samples between batches.
        for (resp, s) in twin.run_batch(&reqs).into_iter().zip(&serial) {
            let resp = resp.unwrap();
            assert_eq!(resp.trajectory, s.trajectory);
            twin.recycle(resp);
        }
        let third = twin.run_batch(&reqs);
        for (b, s) in third.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap().trajectory, s.trajectory);
        }
    }

    #[test]
    fn digital_run_batch_bit_identical_to_serial() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn analog_run_batch_bit_identical_to_serial_noise_free() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin =
            Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn sharded_serial_twin_bit_identical_to_monolithic() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut mono = Lorenz96Twin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut sharded = Lorenz96Twin::analog_opts(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            L96AnalogOpts { shards: 2, ..Default::default() },
        );
        let reqs = mixed_requests();
        let a = mono.run_batch(&reqs);
        let b = sharded.run_batch(&reqs);
        // Serial sharding stays inside the monolithic solver: the backend
        // label must read "analog", not "analog-sharded".
        assert_eq!(b[0].as_ref().unwrap().backend, "analog");
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.as_ref().unwrap().trajectory,
                y.as_ref().unwrap().trajectory,
                "request {k}"
            );
        }
    }

    #[test]
    fn sharded_parallel_twin_reports_backend_and_telemetry() {
        let w = toy_weights(3);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin = Lorenz96Twin::analog_opts(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            L96AnalogOpts { shards: 2, parallel: true, ..Default::default() },
        );
        let resp =
            twin.run(&TwinRequest::autonomous(vec![0.5, -0.25, 0.1], 4));
        let resp = resp.unwrap();
        assert_eq!(resp.backend, "analog-sharded");
        assert_eq!(resp.trajectory.len(), 4);
        let tel = twin.shard_telemetry().expect("sharded backend");
        assert_eq!(tel.len(), 2);
        assert!(tel.iter().all(|s| s.steps > 0));
    }

    #[test]
    fn seeded_noisy_rollouts_identical_across_execution_forms() {
        // One seed, three execution forms (monolithic, serial sharded,
        // parallel fan-out), serial and batched dispatch: every noisy
        // trajectory must be bit-identical to the monolithic serial one.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = |shards, parallel| L96AnalogOpts {
            substeps: 2,
            shards,
            parallel,
        };
        let mut mono =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        let reqs: Vec<TwinRequest> = (0..3)
            .map(|k| {
                TwinRequest::autonomous(
                    (0..d)
                        .map(|i| ((i + k) as f64 * 0.21).sin() * 0.5)
                        .collect(),
                    4,
                )
                .with_seed(900 + k as u64)
            })
            .collect();
        let want: Vec<_> =
            reqs.iter().map(|r| mono.run(r).unwrap()).collect();
        for (label, mut twin) in [
            (
                "monolithic",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false)),
            ),
            (
                "serial sharded",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, false)),
            ),
            (
                "parallel fan-out",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, true)),
            ),
        ] {
            let serial: Vec<_> =
                reqs.iter().map(|r| twin.run(r).unwrap()).collect();
            let batched = twin.run_batch(&reqs);
            for (k, w0) in want.iter().enumerate() {
                assert_eq!(
                    serial[k].trajectory, w0.trajectory,
                    "{label}: serial request {k} diverged"
                );
                assert_eq!(
                    batched[k].as_ref().unwrap().trajectory,
                    w0.trajectory,
                    "{label}: batched request {k} diverged"
                );
                assert_eq!(batched[k].as_ref().unwrap().seed, 900 + k as u64);
            }
        }
    }

    #[test]
    fn ensemble_identical_across_execution_forms() {
        use crate::twin::{ensemble_member_seed, EnsembleSpec};
        // One seed, 8 members, three execution forms: member k equals a
        // standalone rollout seeded with ensemble_member_seed(seed, k),
        // and the pooled stats are identical everywhere.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = |shards, parallel| L96AnalogOpts {
            substeps: 2,
            shards,
            parallel,
        };
        let h0: Vec<f64> =
            (0..d).map(|i| (i as f64 * 0.17).sin() * 0.5).collect();
        let n = 8;
        let req = TwinRequest::autonomous(h0.clone(), 4)
            .with_seed(4242)
            .with_ensemble(
                EnsembleSpec::new(n)
                    .with_percentiles(vec![5.0, 95.0])
                    .with_member_trajectories(),
            );
        let mut reference =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        let want = reference.run(&req).unwrap();
        let want_ens = want.ensemble.as_ref().unwrap();
        assert_eq!(want_ens.members, n);
        // Member k == standalone derived-seed rollout on a fresh twin.
        let mut fresh =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(1, false));
        for (k, member) in
            want_ens.member_trajectories.iter().enumerate()
        {
            let standalone = fresh
                .run(
                    &TwinRequest::autonomous(h0.clone(), 4)
                        .with_seed(ensemble_member_seed(4242, k as u64)),
                )
                .unwrap();
            assert_eq!(
                *member, standalone.trajectory,
                "member {k} != standalone derived-seed rollout"
            );
        }
        for (label, mut twin) in [
            (
                "serial sharded",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, false)),
            ),
            (
                "parallel fan-out",
                Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts(2, true)),
            ),
        ] {
            let got = twin.run(&req).unwrap();
            let ens = got.ensemble.as_ref().unwrap();
            assert_eq!(
                got.trajectory, want.trajectory,
                "{label}: ensemble mean diverged"
            );
            assert_eq!(ens.mean, want_ens.mean, "{label}: mean");
            assert_eq!(ens.std, want_ens.std, "{label}: std");
            assert_eq!(
                ens.percentiles, want_ens.percentiles,
                "{label}: percentiles"
            );
            assert_eq!(
                ens.member_trajectories, want_ens.member_trajectories,
                "{label}: members"
            );
        }
    }

    #[test]
    fn coscheduled_batch_bit_identical_to_per_group_fanout() {
        use crate::twin::EnsembleSpec;
        // A mixed seeded batch that splits into several compatible groups
        // (two n_points values, one ensemble expansion): co-scheduling
        // fuses the groups into one barrier schedule and must not change
        // one output byte — noise on, lane cursors and stats included.
        let d = 34;
        let w = crate::models::loader::decay_mlp_weights(d);
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let opts = L96AnalogOpts {
            substeps: 2,
            shards: 2,
            parallel: true,
        };
        let h0 = |k: usize| -> Vec<f64> {
            (0..d).map(|i| ((i + k) as f64 * 0.13).sin() * 0.4).collect()
        };
        let reqs = vec![
            TwinRequest::autonomous(h0(0), 4).with_seed(11),
            TwinRequest::autonomous(h0(1), 6).with_seed(12),
            TwinRequest::autonomous(h0(2), 4)
                .with_seed(13)
                .with_ensemble(
                    EnsembleSpec::new(3).with_percentiles(vec![10.0, 90.0]),
                ),
            TwinRequest::autonomous(h0(3), 6).with_seed(14),
        ];
        let mut plain = Lorenz96Twin::analog_opts(
            &w, &cfg, noise, 5, opts.clone(),
        );
        let want = plain.run_batch(&reqs);
        let mut fused =
            Lorenz96Twin::analog_opts(&w, &cfg, noise, 5, opts);
        fused.set_coschedule(true);
        let got = fused.run_batch(&reqs);
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            let a = a.as_ref().unwrap();
            let b = b.as_ref().unwrap();
            assert_eq!(
                a.trajectory, b.trajectory,
                "request {k} diverged under co-scheduling"
            );
            assert_eq!(a.seed, b.seed, "request {k} seed");
            match (&a.ensemble, &b.ensemble) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.mean, y.mean, "request {k} mean");
                    assert_eq!(x.std, y.std, "request {k} std");
                    assert_eq!(
                        x.percentiles, y.percentiles,
                        "request {k} percentiles"
                    );
                }
                _ => panic!("request {k}: ensemble presence diverged"),
            }
        }
        // A bad request still fails alone on the co-scheduled path.
        let mixed = vec![
            TwinRequest::autonomous(h0(0), 4).with_seed(21),
            TwinRequest::autonomous(vec![0.0; 3], 4).with_seed(22),
        ];
        let res = fused.run_batch(&mixed);
        assert!(res[0].is_ok());
        assert!(res[1].is_err(), "bad h0 dim must fail alone");
    }

    #[test]
    fn default_h0_resolved_in_batch() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(6));
        let results = twin.run_batch(&[
            TwinRequest::autonomous(vec![], 5),
            TwinRequest::autonomous(vec![0.5; 6], 5),
        ]);
        assert_eq!(
            results[0].as_ref().unwrap().trajectory.row(0),
            &lorenz96::Y0[..]
        );
        assert_eq!(
            results[1].as_ref().unwrap().trajectory.row(0),
            [0.5; 6]
        );
    }

    #[test]
    fn run_batch_isolates_bad_h0_dim() {
        let mut twin = Lorenz96Twin::digital(&toy_weights(3));
        let results = twin.run_batch(&[
            TwinRequest::autonomous(vec![1.0, 2.0, 3.0], 8),
            TwinRequest::autonomous(vec![1.0, 2.0], 8),
            TwinRequest::autonomous(vec![0.0, 0.5, -0.5], 8),
        ]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
    }

    #[test]
    fn recurrent_run_batch_matches_serial() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::from_fn(3, 4, |r, c| 0.05 * ((r + c) % 3) as f64),
            wh: Mat::from_fn(4, 4, |r, c| 0.03 * ((r * 2 + c) % 5) as f64),
            b: vec![0.01; 4],
            wo: Mat::from_fn(4, 3, |r, c| 0.1 * ((r + c) % 2) as f64),
            bo: vec![0.0; 3],
            hidden: 4,
            d_in: 3,
            dt: 0.02,
            kind: "rnn".into(),
        };
        let mut twin = Lorenz96Twin::recurrent(&w).unwrap();
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn unknown_recurrent_kind_errors() {
        use crate::models::loader::RnnWeights;
        let w = RnnWeights {
            wx: Mat::zeros(1, 1),
            wh: Mat::zeros(1, 1),
            b: vec![0.0],
            wo: Mat::zeros(1, 1),
            bo: vec![0.0],
            hidden: 1,
            d_in: 1,
            dt: 0.02,
            kind: "transformer".into(),
        };
        assert!(Lorenz96Twin::recurrent(&w).is_err());
    }
}
