//! The digital-twin layer: one abstraction over the paper's two twins and
//! their execution backends.
//!
//! A twin is a stateful model of a physical asset that can be rolled out
//! from an initial condition; the *backend* decides where the neural ODE
//! actually executes:
//!
//! * `Analog`  — the simulated memristive solver (the paper's system);
//! * `Digital` — Rust-native RK4 over the trained MLP (the "neural ODE on
//!   digital hardware" baseline);
//! * `Pjrt`    — the AOT JAX/Pallas artifact executed through the `xla`
//!   PJRT runtime (the production digital path);
//! * baseline recurrent models (ResNet / RNN / GRU / LSTM) for the
//!   comparison figures.
//!
//! [`registry::TwinRegistry`] maps twin names to factories so the
//! coordinator can spin up per-worker instances.

pub mod hp;
pub mod lorenz96;
pub mod registry;
pub mod setup;

use crate::workload::stimuli::Waveform;

/// A rollout executed on a PJRT artifact: (h0, optional stimulus sampled at
/// half-steps) -> trajectory [n][d]. Constructed by
/// `runtime::artifacts::rollout_fn`.
pub type RolloutFn = Box<
    dyn FnMut(&[f64], Option<&[f64]>) -> anyhow::Result<Vec<Vec<f64>>>
        + Send,
>;

/// A twin-inference request (what the coordinator routes).
#[derive(Debug, Clone)]
pub struct TwinRequest {
    /// Initial state; empty = use the twin's default initial condition.
    pub h0: Vec<f64>,
    /// Number of output samples (incl. the initial one).
    pub n_points: usize,
    /// Stimulus for driven twins (ignored by autonomous ones).
    pub stimulus: Option<Waveform>,
}

impl TwinRequest {
    pub fn autonomous(h0: Vec<f64>, n_points: usize) -> Self {
        Self { h0, n_points, stimulus: None }
    }

    pub fn driven(h0: Vec<f64>, n_points: usize, w: Waveform) -> Self {
        Self { h0, n_points, stimulus: Some(w) }
    }
}

/// A twin-inference response.
#[derive(Debug, Clone)]
pub struct TwinResponse {
    /// [n_points][state_dim] trajectory.
    pub trajectory: Vec<Vec<f64>>,
    /// Which backend produced it (telemetry).
    pub backend: String,
}

/// The object-safe twin interface the coordinator serves.
pub trait Twin: Send {
    /// Twin name (route key).
    fn name(&self) -> &str;

    /// State dimension.
    fn state_dim(&self) -> usize;

    /// Sampling interval of one output step (s).
    fn dt(&self) -> f64;

    /// Default initial condition.
    fn default_h0(&self) -> Vec<f64>;

    /// Execute a request.
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_constructors() {
        let r = TwinRequest::autonomous(vec![1.0], 10);
        assert!(r.stimulus.is_none());
        let d = TwinRequest::driven(
            vec![0.1],
            5,
            Waveform::sine(1.0, 4.0),
        );
        assert!(d.stimulus.is_some());
        assert_eq!(d.n_points, 5);
    }
}
