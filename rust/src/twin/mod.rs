//! The digital-twin layer: one abstraction over the paper's two twins and
//! their execution backends.
//!
//! A twin is a stateful model of a physical asset that can be rolled out
//! from an initial condition; the *backend* decides where the neural ODE
//! actually executes:
//!
//! * `Analog`  — the simulated memristive solver (the paper's system);
//! * `Digital` — Rust-native RK4 over the trained MLP (the "neural ODE on
//!   digital hardware" baseline);
//! * `Pjrt`    — the AOT JAX/Pallas artifact executed through the `xla`
//!   PJRT runtime (the production digital path);
//! * baseline recurrent models (ResNet / RNN / GRU / LSTM) for the
//!   comparison figures.
//!
//! [`registry::TwinRegistry`] maps twin names to factories so the
//! coordinator can spin up per-worker instances.
//!
//! Responses carry flat [`Trajectory`] payloads; the batched entry point
//! is [`Twin::run_batch_into`], which appends into a caller-owned result
//! vector so a warm worker's dispatch loop — and the twins' pooled
//! response trajectories underneath — never touches the allocator in
//! steady state.
//!
//! Monte-Carlo ensembles are first-class requests: a request carrying an
//! [`EnsembleSpec`] expands into N per-member noise lanes executed as one
//! batched rollout, and the response carries pooled per-timestep
//! [`EnsembleStats`] (see the ensemble invariants in `lib.rs`).

pub mod core;
pub mod health;
pub mod hp;
pub mod kuramoto;
pub mod l96two;
pub mod lorenz96;
pub mod registry;
pub mod scenario;
pub mod setup;
pub mod shard;
pub mod throughput;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::derive_stream_seed;
use crate::util::tensor::{Trajectory, TrajectoryPool};
use crate::workload::stimuli::Waveform;

/// A rollout executed on a PJRT artifact: (h0, optional stimulus sampled at
/// half-steps) -> trajectory [n][d]. Constructed by
/// `runtime::artifacts::rollout_fn`.
pub type RolloutFn = Box<
    dyn FnMut(&[f64], Option<&[f64]>) -> anyhow::Result<Vec<Vec<f64>>>
        + Send,
>;

/// Hard cap on ensemble member counts accepted by the serving layer (the
/// router rejects wider specs before admission; one request is one batched
/// rollout, so members bound the rollout's flat state width).
pub const MAX_ENSEMBLE_MEMBERS: usize = 4096;

/// Lane cap per twin sub-batch: group planning counts *effective lanes*
/// (ensemble members, not requests) against this, so one batched solve's
/// scratch footprint stays bounded no matter how many wide ensembles
/// coalesce into a batch. A single request wider than the cap still runs
/// as its own sub-batch (a request is never split across rollouts).
pub const MAX_SUB_BATCH_LANES: usize = 256;

/// Noise seed of ensemble member `k` under a request seed: the replay
/// handle of the per-member lane derivation. The key invariant (enforced
/// by `rust/tests/ensemble.rs`): member `k` of an ensemble rollout is
/// bit-identical to a *standalone* rollout submitted with
/// `TwinRequest::with_seed(ensemble_member_seed(seed, k))`, across batch
/// composition, batch size and shard layout.
pub fn ensemble_member_seed(seed: u64, member: u64) -> u64 {
    derive_stream_seed(seed, member)
}

/// A device-lifetime fault campaign riding on an ensemble request: every
/// member gets its *own* simulated crossbar deployment (yield map seeded
/// by `derive_stream_seed(yield_seed, k)`), optionally salted with extra
/// stuck cells, and aged to `age_s` of virtual device time before the
/// rollout. Pooled statistics then describe a *population of devices*,
/// not noise lanes on one device — the paper's chip-to-chip variability
/// question. Replay is two seeds: the request seed (noise lanes) plus
/// `yield_seed` (hardware population); see `rust/tests/lifetime.rs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultCampaign {
    /// Root seed of the per-member hardware deployments.
    pub yield_seed: u64,
    /// Virtual device age applied to every member before its rollout (s).
    pub age_s: f64,
    /// Extra stuck-cell fraction injected on top of the device config's
    /// intrinsic fault rate (0.0..=1.0).
    pub fault_fraction: f64,
}

impl FaultCampaign {
    pub fn new(yield_seed: u64) -> Self {
        Self { yield_seed, age_s: 0.0, fault_fraction: 0.0 }
    }

    /// Age every member's hardware by `age_s` seconds of virtual time.
    pub fn aged(mut self, age_s: f64) -> Self {
        self.age_s = age_s;
        self
    }

    /// Inject an extra stuck-cell fraction into every member's arrays.
    pub fn with_fault_fraction(mut self, f: f64) -> Self {
        self.fault_fraction = f;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.age_s.is_finite() && self.age_s >= 0.0,
            "fault-campaign age {} must be finite and >= 0",
            self.age_s
        );
        anyhow::ensure!(
            self.fault_fraction.is_finite()
                && (0.0..=1.0).contains(&self.fault_fraction),
            "fault fraction {} outside 0..=1",
            self.fault_fraction
        );
        Ok(())
    }
}

/// A Monte-Carlo ensemble specification: one seed, N noise lanes, one
/// batched rollout, pooled statistics in the response.
#[derive(Debug, Clone, PartialEq)]
pub struct EnsembleSpec {
    /// Member count (N per-member noise lanes in one batched rollout).
    pub members: usize,
    /// Percentile envelope trajectories to return (each in 0..=100, e.g.
    /// `[5.0, 95.0]`); empty = mean/std only.
    pub percentiles: Vec<f64>,
    /// Also return every member trajectory in
    /// [`EnsembleStats::member_trajectories`].
    pub return_members: bool,
    /// Device-lifetime fault campaign: members differ by sampled hardware
    /// (yield map + age), not just noise lanes. Only routes with aging
    /// hardware serve this (others report a per-request error).
    pub fault_campaign: Option<FaultCampaign>,
}

impl EnsembleSpec {
    pub fn new(members: usize) -> Self {
        Self {
            members,
            percentiles: Vec::new(),
            return_members: false,
            fault_campaign: None,
        }
    }

    /// Attach a device-lifetime fault campaign (see [`FaultCampaign`]).
    pub fn with_fault_campaign(mut self, c: FaultCampaign) -> Self {
        self.fault_campaign = Some(c);
        self
    }

    /// Request a percentile envelope (values in 0..=100).
    pub fn with_percentiles(mut self, ps: Vec<f64>) -> Self {
        self.percentiles = ps;
        self
    }

    /// Also return the per-member trajectories.
    pub fn with_member_trajectories(mut self) -> Self {
        self.return_members = true;
        self
    }

    /// Validate the spec (the router calls this before admission; twins
    /// re-check so direct callers get per-request errors, not panics).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.members >= 1, "ensemble needs >= 1 member");
        anyhow::ensure!(
            self.members <= MAX_ENSEMBLE_MEMBERS,
            "ensemble of {} members exceeds the cap of {}",
            self.members,
            MAX_ENSEMBLE_MEMBERS
        );
        for &p in &self.percentiles {
            anyhow::ensure!(
                p.is_finite() && (0.0..=100.0).contains(&p),
                "percentile {p} outside 0..=100"
            );
        }
        if let Some(c) = &self.fault_campaign {
            c.validate()?;
        }
        Ok(())
    }
}

/// A twin-inference request (what the coordinator routes).
#[derive(Debug, Clone)]
pub struct TwinRequest {
    /// Initial state; empty = use the twin's default initial condition.
    pub h0: Vec<f64>,
    /// Number of output samples (incl. the initial one).
    pub n_points: usize,
    /// Stimulus for driven twins (ignored by autonomous ones).
    pub stimulus: Option<Waveform>,
    /// Noise-lane seed. `Some(s)` pins the rollout's per-trajectory noise
    /// stream, making a noisy analogue rollout bit-reproducible regardless
    /// of batch size, batch composition or shard layout. `None` lets the
    /// serving layer derive one (the router stamps it; standalone twins
    /// auto-derive); either way the seed actually used is echoed in
    /// [`TwinResponse::seed`] for replay.
    pub seed: Option<u64>,
    /// Monte-Carlo ensemble: expand this request into
    /// `EnsembleSpec::members` noise lanes (member `k` seeded by
    /// [`ensemble_member_seed`]) executed as a single batched rollout, and
    /// return pooled [`EnsembleStats`]. Twins without a batched ensemble
    /// path report a per-request error rather than silently downgrading.
    pub ensemble: Option<EnsembleSpec>,
}

impl TwinRequest {
    pub fn autonomous(h0: Vec<f64>, n_points: usize) -> Self {
        Self { h0, n_points, stimulus: None, seed: None, ensemble: None }
    }

    pub fn driven(h0: Vec<f64>, n_points: usize, w: Waveform) -> Self {
        Self { h0, n_points, stimulus: Some(w), seed: None, ensemble: None }
    }

    /// Pin the noise-lane seed (replay a previous response's
    /// [`TwinResponse::seed`]).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Attach a Monte-Carlo ensemble spec.
    pub fn with_ensemble(mut self, spec: EnsembleSpec) -> Self {
        self.ensemble = Some(spec);
        self
    }

    /// Effective trajectory lanes this request contributes to a batched
    /// rollout (ensemble members, else 1) — what the batcher and the
    /// twins' group planning count against capacity.
    pub fn lanes(&self) -> usize {
        self.ensemble.as_ref().map_or(1, |e| e.members.max(1))
    }
}

/// Per-timestep statistics of a Monte-Carlo ensemble rollout.
///
/// Every trajectory here is drawn from the twin's [`TrajectoryPool`];
/// handing the response back via the twin's `recycle` returns them (and
/// the emptied container shells) so a warm ensemble batch allocates
/// nothing in steady state.
#[derive(Debug, Clone, Default)]
pub struct EnsembleStats {
    /// Member count of the rollout.
    pub members: usize,
    /// Per-timestep ensemble mean, `[n_points][dim]`.
    pub mean: Trajectory,
    /// Per-timestep ensemble standard deviation (population), NaN where
    /// no member produced a finite sample.
    pub std: Trajectory,
    /// Requested percentile envelopes: `(p, trajectory)` pairs in the
    /// order of [`EnsembleSpec::percentiles`].
    pub percentiles: Vec<(f64, Trajectory)>,
    /// Per-member trajectories (only when
    /// [`EnsembleSpec::return_members`] was set); member `k` replays
    /// standalone under [`ensemble_member_seed`]`(seed, k)`.
    pub member_trajectories: Vec<Trajectory>,
    /// NaN samples the moment accumulator skipped (diverged members).
    pub nan_samples: u64,
}

impl EnsembleStats {
    /// Return every pooled trajectory to `pool`, leaving an empty shell
    /// whose container capacities survive for reuse (the twins keep a
    /// free-list of shells to close the zero-allocation loop).
    pub fn reclaim(&mut self, pool: &mut TrajectoryPool) {
        pool.put(std::mem::take(&mut self.mean));
        pool.put(std::mem::take(&mut self.std));
        for (_, t) in self.percentiles.drain(..) {
            pool.put(t);
        }
        for t in self.member_trajectories.drain(..) {
            pool.put(t);
        }
        self.members = 0;
        self.nan_samples = 0;
    }
}

/// Lane-slot location of one ensemble inside a flat batched rollout
/// whose rows are `batch * dim` wide.
#[derive(Debug, Clone, Copy)]
pub(crate) struct EnsembleSlot {
    /// Total lanes in the rollout.
    pub batch: usize,
    /// Per-trajectory state dimension.
    pub dim: usize,
    /// First lane slot of this ensemble.
    pub base: usize,
}

/// Assemble pooled ensemble statistics for the ensemble occupying lane
/// slots `slot.base .. slot.base + spec.members` of a flat batched
/// rollout. Shared by the HP and Lorenz96 twins' batched paths; every
/// output buffer comes from `pool` and the container shells are reused
/// (`shell` should be a recycled [`EnsembleStats`]), so a warm call is
/// allocation-free. Returns the response trajectory (a pooled copy of
/// the ensemble mean) and the filled stats payload.
pub(crate) fn assemble_ensemble_stats(
    spec: &EnsembleSpec,
    flat: &Trajectory,
    slot: EnsembleSlot,
    acc: &mut crate::util::stats::EnsembleAccumulator,
    pool: &mut TrajectoryPool,
    mut shell: EnsembleStats,
) -> (Trajectory, EnsembleStats) {
    let EnsembleSlot { batch, dim, base } = slot;
    let n = spec.members;
    acc.begin(dim, flat.len(), pool);
    for m in 0..n {
        let lo = (base + m) * dim;
        acc.add_member_rows(flat.iter().map(|row| &row[lo..lo + dim]));
    }
    let (mean, std, nan) = acc.finish();
    // One gather + sort per element serves every requested percentile.
    for &p in &spec.percentiles {
        shell.percentiles.push((p, pool.get(dim)));
    }
    acc.percentile_pairs_flat_into(flat, base, n, &mut shell.percentiles);
    if spec.return_members {
        for m in 0..n {
            let mut t = pool.get(dim);
            crate::ode::batch::unbatch_into(
                flat,
                batch,
                dim,
                base + m,
                &mut t,
            );
            shell.member_trajectories.push(t);
        }
    }
    let mut resp_traj = pool.get(dim);
    resp_traj.extend_rows(&mean);
    shell.members = n;
    shell.mean = mean;
    shell.std = std;
    shell.nan_samples = nan;
    (resp_traj, shell)
}

/// A twin-inference response.
///
/// The trajectory is flat ([`Trajectory`], row = one sample) and the
/// backend label is `&'static str` — both deliberate: a response carries
/// exactly one heap buffer, and twins draw that buffer from a pool so a
/// warm batch path allocates nothing (see the perf invariants in
/// `lib.rs`). Ensemble responses additionally carry pooled
/// [`EnsembleStats`].
#[derive(Debug, Clone)]
pub struct TwinResponse {
    /// [n_points][state_dim] trajectory, stored flat. For ensemble
    /// requests this is the ensemble *mean* (the stats payload holds the
    /// spread and, optionally, the members).
    pub trajectory: Trajectory,
    /// Which backend produced it (telemetry).
    pub backend: &'static str,
    /// The noise-lane seed this rollout used (the request's, or the
    /// auto-derived one): resubmitting with `TwinRequest::with_seed(seed)`
    /// replays a noisy analogue rollout bit for bit. For ensembles the
    /// seed is the *family* root; member `k` replays standalone under
    /// [`ensemble_member_seed`]`(seed, k)`.
    pub seed: u64,
    /// Ensemble statistics (present iff the request carried an
    /// [`EnsembleSpec`] and the twin served it).
    pub ensemble: Option<EnsembleStats>,
    /// `true` iff a health-monitored route served this from its *fallback*
    /// backend because the analogue hardware failed recalibration (see
    /// [`health::MonitoredTwin`]). Plain twins always stamp `false` —
    /// degraded service is flagged, never silent (lifetime invariant 3 in
    /// `lib.rs`).
    pub degraded: bool,
}

/// Root of the trait fallback's auto-derived seed family (fixed constant:
/// seeds exist for replay, not secrecy — see the router's seed root).
const FALLBACK_SEED_ROOT: u64 = 0xfa11_bac5_eed0_0003;

/// Process-global sequence behind [`fallback_auto_seed`]. Per-twin state
/// would be nicer, but the trait default cannot carry any — a shared
/// counter still guarantees the two properties that matter: every
/// seedless fallback request gets a *distinct* seed, and the echoed seed
/// replays the rollout bit for bit.
static FALLBACK_SEED_SEQ: AtomicU64 = AtomicU64::new(1);

/// Next auto-derived seed for a seedless request on the trait's serial
/// fallback path — mirrors the twins' `SeedSequencer` resolution (a real
/// replayable seed, echoed in the response) for twins without one.
fn fallback_auto_seed() -> u64 {
    derive_stream_seed(
        FALLBACK_SEED_ROOT,
        FALLBACK_SEED_SEQ.fetch_add(1, Ordering::Relaxed),
    )
}

/// The object-safe twin interface the coordinator serves.
pub trait Twin: Send {
    /// Twin name (route key).
    fn name(&self) -> &str;

    /// State dimension.
    fn state_dim(&self) -> usize;

    /// Sampling interval of one output step (s).
    fn dt(&self) -> f64;

    /// Default initial condition.
    fn default_h0(&self) -> Vec<f64>;

    /// Execute a request.
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse>;

    /// Execute a whole batch of requests, returning one result per request
    /// in order. Failures are per-request: one bad job must never poison
    /// its batch-mates.
    ///
    /// The default is the serial fallback (`run` per request), so every
    /// twin keeps working under the coordinator's batch dispatch. Seedless
    /// requests are stamped with a fresh auto-derived seed *before* `run`
    /// sees them, so fallback twins echo a real, replayable seed instead
    /// of a fake `0` (the seed-echo contract: resubmitting the echoed seed
    /// reproduces the rollout bit for bit). Twins with a real batched
    /// rollout (the analogue solver's multi-vector crossbar reads, the
    /// digital backends' per-layer GEMMs) override this (or
    /// [`Twin::run_batch_into`]); implementations split incompatible
    /// requests into compatible sub-batches (see [`GroupPlan`]) rather
    /// than padding, and their batched trajectories are bit-identical to
    /// serial `run` calls with the same seeds — noise off *and* noise on
    /// (per-trajectory noise lanes; see the noise-determinism invariants
    /// in `lib.rs`).
    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<anyhow::Result<TwinResponse>> {
        reqs.iter()
            .map(|r| {
                if r.seed.is_none() {
                    let mut seeded = r.clone();
                    seeded.seed = Some(fallback_auto_seed());
                    self.run(&seeded)
                } else {
                    self.run(r)
                }
            })
            .collect()
    }

    /// Append one result per request (in order) to `out` — the
    /// scheduler-facing form of [`Twin::run_batch`]. The caller owns and
    /// reuses `out`, so a warm worker's dispatch loop allocates no result
    /// vector per batch; twins with pooled response trajectories extend
    /// that to a fully allocation-free steady state. The default routes
    /// through `run_batch`, so overriding `run_batch` alone is enough;
    /// a twin overriding *this* method must also override `run_batch` to
    /// delegate here (as the HP and Lorenz96 twins do), or the two entry
    /// points diverge.
    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<anyhow::Result<TwinResponse>>,
    ) {
        out.extend(self.run_batch(reqs));
    }
}

/// Reusable batch-compatibility plan: request indices grouped into
/// sub-batches that share `n_points` (one rollout length per batched
/// solve), while h0 and stimulus may differ per trajectory. Groups come
/// out in ascending `n_points`; submission order is preserved within each
/// group, and nothing is padded — a mixed batch simply splits.
///
/// Capacity is counted in *lanes*, not requests
/// ([`GroupPlan::plan_lanes`]): an ensemble request weighs
/// `EnsembleSpec::members` trajectories, so a sub-batch's flat rollout
/// width stays bounded by the lane cap no matter how requests and
/// ensembles mix.
///
/// The plan owns its index storage and sorts in place
/// (`sort_unstable_by_key` allocates nothing), so replanning on a warm
/// instance is allocation-free — this is what the twins' `run_batch_into`
/// overrides use instead of building fresh maps per batch.
#[derive(Debug, Default)]
pub struct GroupPlan {
    /// Request indices, sorted by (n_points, submission order).
    order: Vec<usize>,
    /// Half-open (start, end) ranges into `order`, one per group.
    bounds: Vec<(usize, usize)>,
}

impl GroupPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild the plan for `reqs` (reuses internal buffers) with no lane
    /// cap — groups split on `n_points` only.
    pub fn plan(&mut self, reqs: &[TwinRequest]) {
        self.plan_lanes(reqs, usize::MAX);
    }

    /// Rebuild the plan, additionally splitting groups so no sub-batch
    /// exceeds `max_lanes` effective trajectories (requests weighted by
    /// [`TwinRequest::lanes`]). A single request wider than the cap gets
    /// its own group — a request is never split across rollouts.
    pub fn plan_lanes(&mut self, reqs: &[TwinRequest], max_lanes: usize) {
        self.order.clear();
        self.order.extend(0..reqs.len());
        self.order.sort_unstable_by_key(|&i| (reqs[i].n_points, i));
        self.bounds.clear();
        let mut start = 0;
        let mut lanes = 0usize;
        for k in 0..self.order.len() {
            let w = reqs[self.order[k]].lanes();
            let split_n_points = k > start
                && reqs[self.order[k]].n_points
                    != reqs[self.order[start]].n_points;
            let split_cap =
                k > start && lanes.saturating_add(w) > max_lanes;
            if split_n_points || split_cap {
                self.bounds.push((start, k));
                start = k;
                lanes = 0;
            }
            lanes = lanes.saturating_add(w);
        }
        if start < self.order.len() {
            self.bounds.push((start, self.order.len()));
        }
    }

    pub fn n_groups(&self) -> usize {
        self.bounds.len()
    }

    /// Request indices of group `g`, in submission order.
    pub fn group(&self, g: usize) -> &[usize] {
        let (s, e) = self.bounds[g];
        &self.order[s..e]
    }
}

/// Group request indices into batch-compatible sub-batches (allocating
/// convenience over [`GroupPlan`] for inspection and tests).
pub fn compatible_groups(reqs: &[TwinRequest]) -> Vec<Vec<usize>> {
    let mut plan = GroupPlan::new();
    plan.plan(reqs);
    (0..plan.n_groups()).map(|g| plan.group(g).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatible_groups_split_by_n_points() {
        let reqs = vec![
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
        ];
        let groups = compatible_groups(&reqs);
        assert_eq!(groups, vec![vec![0, 2, 4], vec![1, 3]]);
        // Every index appears exactly once.
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn group_plan_is_reusable() {
        let mut plan = GroupPlan::new();
        let reqs = vec![
            TwinRequest::autonomous(vec![], 7),
            TwinRequest::autonomous(vec![], 3),
            TwinRequest::autonomous(vec![], 7),
        ];
        plan.plan(&reqs);
        assert_eq!(plan.n_groups(), 2);
        assert_eq!(plan.group(0), [1]);
        assert_eq!(plan.group(1), [0, 2]);
        // Replan with a different shape: old state fully replaced.
        let reqs2 = vec![TwinRequest::autonomous(vec![], 5)];
        plan.plan(&reqs2);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.group(0), [0]);
    }

    #[test]
    fn default_run_batch_is_serial_fallback() {
        struct Echo;
        impl Twin for Echo {
            fn name(&self) -> &str {
                "echo"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                anyhow::ensure!(req.n_points > 0, "empty request");
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &req.h0,
                        req.n_points,
                    ),
                    backend: "echo",
                    seed: req.seed.unwrap_or(0),
                    ensemble: None,
                    degraded: false,
                })
            }
        }
        let mut t = Echo;
        let reqs = vec![
            TwinRequest::autonomous(vec![1.0], 2),
            TwinRequest::autonomous(vec![2.0], 0),
            TwinRequest::autonomous(vec![3.0], 3),
        ];
        let results = t.run_batch(&reqs);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().trajectory.len(), 2);
        assert!(results[1].is_err(), "per-request failure isolated");
        assert_eq!(
            results[2].as_ref().unwrap().trajectory.row(0),
            [3.0]
        );
        // run_batch_into appends to a caller-owned vector.
        let mut out = Vec::new();
        t.run_batch_into(&reqs, &mut out);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[1].is_err() && out[2].is_ok());
    }

    #[test]
    fn request_constructors() {
        let r = TwinRequest::autonomous(vec![1.0], 10);
        assert!(r.stimulus.is_none());
        assert!(r.seed.is_none());
        assert!(r.ensemble.is_none());
        assert_eq!(r.lanes(), 1);
        let d = TwinRequest::driven(
            vec![0.1],
            5,
            Waveform::sine(1.0, 4.0),
        );
        assert!(d.stimulus.is_some());
        assert_eq!(d.n_points, 5);
        let s = TwinRequest::autonomous(vec![], 2).with_seed(99);
        assert_eq!(s.seed, Some(99));
        let e = TwinRequest::autonomous(vec![], 2)
            .with_ensemble(EnsembleSpec::new(16));
        assert_eq!(e.lanes(), 16);
    }

    #[test]
    fn ensemble_spec_validation() {
        assert!(EnsembleSpec::new(1).validate().is_ok());
        assert!(EnsembleSpec::new(32)
            .with_percentiles(vec![5.0, 95.0])
            .validate()
            .is_ok());
        assert!(EnsembleSpec::new(0).validate().is_err());
        assert!(EnsembleSpec::new(MAX_ENSEMBLE_MEMBERS + 1)
            .validate()
            .is_err());
        assert!(EnsembleSpec::new(4)
            .with_percentiles(vec![101.0])
            .validate()
            .is_err());
        assert!(EnsembleSpec::new(4)
            .with_percentiles(vec![f64::NAN])
            .validate()
            .is_err());
        // Fault campaigns validate through the spec.
        assert!(EnsembleSpec::new(4)
            .with_fault_campaign(
                FaultCampaign::new(9).aged(1e6).with_fault_fraction(0.1)
            )
            .validate()
            .is_ok());
        assert!(EnsembleSpec::new(4)
            .with_fault_campaign(FaultCampaign::new(9).aged(-1.0))
            .validate()
            .is_err());
        assert!(EnsembleSpec::new(4)
            .with_fault_campaign(
                FaultCampaign::new(9).with_fault_fraction(1.5)
            )
            .validate()
            .is_err());
    }

    #[test]
    fn ensemble_member_seed_matches_lane_derivation() {
        use crate::util::rng::NoiseLane;
        // The replay contract: a standalone request seeded with
        // ensemble_member_seed(s, k) builds exactly the lane the batched
        // ensemble uses for member k.
        let s = 0xfeed;
        for k in 0..4 {
            assert_eq!(
                NoiseLane::from_seed(ensemble_member_seed(s, k)),
                NoiseLane::from_seed(derive_stream_seed(s, k)),
            );
        }
        assert_ne!(
            ensemble_member_seed(s, 0),
            ensemble_member_seed(s, 1)
        );
    }

    #[test]
    fn plan_lanes_counts_members_not_requests() {
        let mut plan = GroupPlan::new();
        let reqs = vec![
            TwinRequest::autonomous(vec![], 10)
                .with_ensemble(EnsembleSpec::new(6)),
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 10)
                .with_ensemble(EnsembleSpec::new(4)),
            TwinRequest::autonomous(vec![], 10),
        ];
        // Cap 8 lanes: [6, 1] fits, the 4-wide ensemble splits off, the
        // trailing plain request rides with it (4 + 1 <= 8).
        plan.plan_lanes(&reqs, 8);
        assert_eq!(plan.n_groups(), 2);
        assert_eq!(plan.group(0), [0, 1]);
        assert_eq!(plan.group(1), [2, 3]);
        // A single over-cap ensemble still gets its own (whole) group.
        let wide = vec![
            TwinRequest::autonomous(vec![], 5)
                .with_ensemble(EnsembleSpec::new(100)),
            TwinRequest::autonomous(vec![], 5),
        ];
        plan.plan_lanes(&wide, 8);
        assert_eq!(plan.n_groups(), 2);
        assert_eq!(plan.group(0), [0]);
        assert_eq!(plan.group(1), [1]);
        // No cap: identical to plain planning.
        plan.plan_lanes(&reqs, usize::MAX);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.group(0), [0, 1, 2, 3]);
    }

    #[test]
    fn serial_fallback_stamps_real_seeds() {
        // A fallback twin whose run echoes the request seed verbatim:
        // seedless requests through run_batch must come back with
        // distinct, non-placeholder seeds (the seed-echo bugfix).
        struct Echo2;
        impl Twin for Echo2 {
            fn name(&self) -> &str {
                "echo2"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                let seed =
                    req.seed.expect("fallback must stamp a seed");
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &[seed as f64],
                        req.n_points,
                    ),
                    backend: "echo2",
                    seed,
                    ensemble: None,
                    degraded: false,
                })
            }
        }
        let mut t = Echo2;
        let reqs = vec![
            TwinRequest::autonomous(vec![], 1),
            TwinRequest::autonomous(vec![], 1),
            TwinRequest::autonomous(vec![], 1).with_seed(42),
        ];
        let out = t.run_batch(&reqs);
        let s0 = out[0].as_ref().unwrap().seed;
        let s1 = out[1].as_ref().unwrap().seed;
        assert_ne!(s0, 0, "fallback echoed the fake seed 0");
        assert_ne!(s0, s1, "fallback reused a seed");
        assert_eq!(out[2].as_ref().unwrap().seed, 42, "explicit seed");
    }

    #[test]
    fn ensemble_stats_reclaim_returns_buffers() {
        let mut pool = TrajectoryPool::new();
        let mut stats = EnsembleStats {
            members: 3,
            mean: Trajectory::zeros(2, 4),
            std: Trajectory::zeros(2, 4),
            percentiles: vec![(5.0, Trajectory::zeros(2, 4))],
            member_trajectories: vec![Trajectory::zeros(2, 4)],
            nan_samples: 1,
        };
        stats.reclaim(&mut pool);
        assert_eq!(pool.len(), 4);
        assert_eq!(stats.members, 0);
        assert!(stats.percentiles.is_empty());
        assert!(stats.member_trajectories.is_empty());
    }
}
