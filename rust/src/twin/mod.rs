//! The digital-twin layer: one abstraction over the paper's two twins and
//! their execution backends.
//!
//! A twin is a stateful model of a physical asset that can be rolled out
//! from an initial condition; the *backend* decides where the neural ODE
//! actually executes:
//!
//! * `Analog`  — the simulated memristive solver (the paper's system);
//! * `Digital` — Rust-native RK4 over the trained MLP (the "neural ODE on
//!   digital hardware" baseline);
//! * `Pjrt`    — the AOT JAX/Pallas artifact executed through the `xla`
//!   PJRT runtime (the production digital path);
//! * baseline recurrent models (ResNet / RNN / GRU / LSTM) for the
//!   comparison figures.
//!
//! [`registry::TwinRegistry`] maps twin names to factories so the
//! coordinator can spin up per-worker instances.
//!
//! Responses carry flat [`Trajectory`] payloads; the batched entry point
//! is [`Twin::run_batch_into`], which appends into a caller-owned result
//! vector so a warm worker's dispatch loop — and the twins' pooled
//! response trajectories underneath — never touches the allocator in
//! steady state.

pub mod hp;
pub mod lorenz96;
pub mod registry;
pub mod setup;
pub mod shard;
pub mod throughput;

use crate::util::tensor::Trajectory;
use crate::workload::stimuli::Waveform;

/// A rollout executed on a PJRT artifact: (h0, optional stimulus sampled at
/// half-steps) -> trajectory [n][d]. Constructed by
/// `runtime::artifacts::rollout_fn`.
pub type RolloutFn = Box<
    dyn FnMut(&[f64], Option<&[f64]>) -> anyhow::Result<Vec<Vec<f64>>>
        + Send,
>;

/// A twin-inference request (what the coordinator routes).
#[derive(Debug, Clone)]
pub struct TwinRequest {
    /// Initial state; empty = use the twin's default initial condition.
    pub h0: Vec<f64>,
    /// Number of output samples (incl. the initial one).
    pub n_points: usize,
    /// Stimulus for driven twins (ignored by autonomous ones).
    pub stimulus: Option<Waveform>,
    /// Noise-lane seed. `Some(s)` pins the rollout's per-trajectory noise
    /// stream, making a noisy analogue rollout bit-reproducible regardless
    /// of batch size, batch composition or shard layout. `None` lets the
    /// serving layer derive one (the router stamps it; standalone twins
    /// auto-derive); either way the seed actually used is echoed in
    /// [`TwinResponse::seed`] for replay.
    pub seed: Option<u64>,
}

impl TwinRequest {
    pub fn autonomous(h0: Vec<f64>, n_points: usize) -> Self {
        Self { h0, n_points, stimulus: None, seed: None }
    }

    pub fn driven(h0: Vec<f64>, n_points: usize, w: Waveform) -> Self {
        Self { h0, n_points, stimulus: Some(w), seed: None }
    }

    /// Pin the noise-lane seed (replay a previous response's
    /// [`TwinResponse::seed`]).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }
}

/// A twin-inference response.
///
/// The trajectory is flat ([`Trajectory`], row = one sample) and the
/// backend label is `&'static str` — both deliberate: a response carries
/// exactly one heap buffer, and twins draw that buffer from a pool so a
/// warm batch path allocates nothing (see the perf invariants in
/// `lib.rs`).
#[derive(Debug, Clone)]
pub struct TwinResponse {
    /// [n_points][state_dim] trajectory, stored flat.
    pub trajectory: Trajectory,
    /// Which backend produced it (telemetry).
    pub backend: &'static str,
    /// The noise-lane seed this rollout used (the request's, or the
    /// auto-derived one): resubmitting with `TwinRequest::with_seed(seed)`
    /// replays a noisy analogue rollout bit for bit.
    pub seed: u64,
}

/// The object-safe twin interface the coordinator serves.
pub trait Twin: Send {
    /// Twin name (route key).
    fn name(&self) -> &str;

    /// State dimension.
    fn state_dim(&self) -> usize;

    /// Sampling interval of one output step (s).
    fn dt(&self) -> f64;

    /// Default initial condition.
    fn default_h0(&self) -> Vec<f64>;

    /// Execute a request.
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse>;

    /// Execute a whole batch of requests, returning one result per request
    /// in order. Failures are per-request: one bad job must never poison
    /// its batch-mates.
    ///
    /// The default is the serial fallback (`run` per request), so every
    /// twin keeps working under the coordinator's batch dispatch. Twins
    /// with a real batched rollout (the analogue solver's multi-vector
    /// crossbar reads, the digital backends' per-layer GEMMs) override
    /// this (or [`Twin::run_batch_into`]); implementations split
    /// incompatible requests into compatible sub-batches (see
    /// [`GroupPlan`]) rather than padding, and their batched trajectories
    /// are bit-identical to serial `run` calls with the same seeds —
    /// noise off *and* noise on (per-trajectory noise lanes; see the
    /// noise-determinism invariants in `lib.rs`).
    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<anyhow::Result<TwinResponse>> {
        reqs.iter().map(|r| self.run(r)).collect()
    }

    /// Append one result per request (in order) to `out` — the
    /// scheduler-facing form of [`Twin::run_batch`]. The caller owns and
    /// reuses `out`, so a warm worker's dispatch loop allocates no result
    /// vector per batch; twins with pooled response trajectories extend
    /// that to a fully allocation-free steady state. The default routes
    /// through `run_batch`, so overriding `run_batch` alone is enough;
    /// a twin overriding *this* method must also override `run_batch` to
    /// delegate here (as the HP and Lorenz96 twins do), or the two entry
    /// points diverge.
    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<anyhow::Result<TwinResponse>>,
    ) {
        out.extend(self.run_batch(reqs));
    }
}

/// Reusable batch-compatibility plan: request indices grouped into
/// sub-batches that share `n_points` (one rollout length per batched
/// solve), while h0 and stimulus may differ per trajectory. Groups come
/// out in ascending `n_points`; submission order is preserved within each
/// group, and nothing is padded — a mixed batch simply splits.
///
/// The plan owns its index storage and sorts in place
/// (`sort_unstable_by_key` allocates nothing), so replanning on a warm
/// instance is allocation-free — this is what the twins' `run_batch_into`
/// overrides use instead of building fresh maps per batch.
#[derive(Debug, Default)]
pub struct GroupPlan {
    /// Request indices, sorted by (n_points, submission order).
    order: Vec<usize>,
    /// Half-open (start, end) ranges into `order`, one per group.
    bounds: Vec<(usize, usize)>,
}

impl GroupPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild the plan for `reqs` (reuses internal buffers).
    pub fn plan(&mut self, reqs: &[TwinRequest]) {
        self.order.clear();
        self.order.extend(0..reqs.len());
        self.order.sort_unstable_by_key(|&i| (reqs[i].n_points, i));
        self.bounds.clear();
        let mut start = 0;
        for k in 1..=self.order.len() {
            if k == self.order.len()
                || reqs[self.order[k]].n_points
                    != reqs[self.order[start]].n_points
            {
                self.bounds.push((start, k));
                start = k;
            }
        }
    }

    pub fn n_groups(&self) -> usize {
        self.bounds.len()
    }

    /// Request indices of group `g`, in submission order.
    pub fn group(&self, g: usize) -> &[usize] {
        let (s, e) = self.bounds[g];
        &self.order[s..e]
    }
}

/// Group request indices into batch-compatible sub-batches (allocating
/// convenience over [`GroupPlan`] for inspection and tests).
pub fn compatible_groups(reqs: &[TwinRequest]) -> Vec<Vec<usize>> {
    let mut plan = GroupPlan::new();
    plan.plan(reqs);
    (0..plan.n_groups()).map(|g| plan.group(g).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatible_groups_split_by_n_points() {
        let reqs = vec![
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
        ];
        let groups = compatible_groups(&reqs);
        assert_eq!(groups, vec![vec![0, 2, 4], vec![1, 3]]);
        // Every index appears exactly once.
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn group_plan_is_reusable() {
        let mut plan = GroupPlan::new();
        let reqs = vec![
            TwinRequest::autonomous(vec![], 7),
            TwinRequest::autonomous(vec![], 3),
            TwinRequest::autonomous(vec![], 7),
        ];
        plan.plan(&reqs);
        assert_eq!(plan.n_groups(), 2);
        assert_eq!(plan.group(0), [1]);
        assert_eq!(plan.group(1), [0, 2]);
        // Replan with a different shape: old state fully replaced.
        let reqs2 = vec![TwinRequest::autonomous(vec![], 5)];
        plan.plan(&reqs2);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.group(0), [0]);
    }

    #[test]
    fn default_run_batch_is_serial_fallback() {
        struct Echo;
        impl Twin for Echo {
            fn name(&self) -> &str {
                "echo"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                anyhow::ensure!(req.n_points > 0, "empty request");
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &req.h0,
                        req.n_points,
                    ),
                    backend: "echo",
                    seed: req.seed.unwrap_or(0),
                })
            }
        }
        let mut t = Echo;
        let reqs = vec![
            TwinRequest::autonomous(vec![1.0], 2),
            TwinRequest::autonomous(vec![2.0], 0),
            TwinRequest::autonomous(vec![3.0], 3),
        ];
        let results = t.run_batch(&reqs);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().trajectory.len(), 2);
        assert!(results[1].is_err(), "per-request failure isolated");
        assert_eq!(
            results[2].as_ref().unwrap().trajectory.row(0),
            [3.0]
        );
        // run_batch_into appends to a caller-owned vector.
        let mut out = Vec::new();
        t.run_batch_into(&reqs, &mut out);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[1].is_err() && out[2].is_ok());
    }

    #[test]
    fn request_constructors() {
        let r = TwinRequest::autonomous(vec![1.0], 10);
        assert!(r.stimulus.is_none());
        assert!(r.seed.is_none());
        let d = TwinRequest::driven(
            vec![0.1],
            5,
            Waveform::sine(1.0, 4.0),
        );
        assert!(d.stimulus.is_some());
        assert_eq!(d.n_points, 5);
        let s = TwinRequest::autonomous(vec![], 2).with_seed(99);
        assert_eq!(s.seed, Some(99));
    }
}
