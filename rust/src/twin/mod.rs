//! The digital-twin layer: one abstraction over the paper's two twins and
//! their execution backends.
//!
//! A twin is a stateful model of a physical asset that can be rolled out
//! from an initial condition; the *backend* decides where the neural ODE
//! actually executes:
//!
//! * `Analog`  — the simulated memristive solver (the paper's system);
//! * `Digital` — Rust-native RK4 over the trained MLP (the "neural ODE on
//!   digital hardware" baseline);
//! * `Pjrt`    — the AOT JAX/Pallas artifact executed through the `xla`
//!   PJRT runtime (the production digital path);
//! * baseline recurrent models (ResNet / RNN / GRU / LSTM) for the
//!   comparison figures.
//!
//! [`registry::TwinRegistry`] maps twin names to factories so the
//! coordinator can spin up per-worker instances.

pub mod hp;
pub mod lorenz96;
pub mod registry;
pub mod setup;

use crate::workload::stimuli::Waveform;

/// A rollout executed on a PJRT artifact: (h0, optional stimulus sampled at
/// half-steps) -> trajectory [n][d]. Constructed by
/// `runtime::artifacts::rollout_fn`.
pub type RolloutFn = Box<
    dyn FnMut(&[f64], Option<&[f64]>) -> anyhow::Result<Vec<Vec<f64>>>
        + Send,
>;

/// A twin-inference request (what the coordinator routes).
#[derive(Debug, Clone)]
pub struct TwinRequest {
    /// Initial state; empty = use the twin's default initial condition.
    pub h0: Vec<f64>,
    /// Number of output samples (incl. the initial one).
    pub n_points: usize,
    /// Stimulus for driven twins (ignored by autonomous ones).
    pub stimulus: Option<Waveform>,
}

impl TwinRequest {
    pub fn autonomous(h0: Vec<f64>, n_points: usize) -> Self {
        Self { h0, n_points, stimulus: None }
    }

    pub fn driven(h0: Vec<f64>, n_points: usize, w: Waveform) -> Self {
        Self { h0, n_points, stimulus: Some(w) }
    }
}

/// A twin-inference response.
#[derive(Debug, Clone)]
pub struct TwinResponse {
    /// [n_points][state_dim] trajectory.
    pub trajectory: Vec<Vec<f64>>,
    /// Which backend produced it (telemetry).
    pub backend: String,
}

/// The object-safe twin interface the coordinator serves.
pub trait Twin: Send {
    /// Twin name (route key).
    fn name(&self) -> &str;

    /// State dimension.
    fn state_dim(&self) -> usize;

    /// Sampling interval of one output step (s).
    fn dt(&self) -> f64;

    /// Default initial condition.
    fn default_h0(&self) -> Vec<f64>;

    /// Execute a request.
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse>;

    /// Execute a whole batch of requests, returning one result per request
    /// in order. Failures are per-request: one bad job must never poison
    /// its batch-mates.
    ///
    /// The default is the serial fallback (`run` per request), so every
    /// twin keeps working under the coordinator's batch dispatch. Twins
    /// with a real batched rollout (the analogue solver's multi-vector
    /// crossbar reads, the digital backends' per-layer GEMMs) override
    /// this; implementations split incompatible requests into compatible
    /// sub-batches via [`compatible_groups`] rather than padding, and with
    /// noise off their batched trajectories are bit-identical to serial
    /// `run` calls.
    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<anyhow::Result<TwinResponse>> {
        reqs.iter().map(|r| self.run(r)).collect()
    }
}

/// Group request indices into batch-compatible sub-batches: requests in a
/// group share `n_points` (one rollout length per batched solve), while h0
/// and stimulus may differ per trajectory. Submission order is preserved
/// within each group, and nothing is padded — a mixed batch simply splits.
pub fn compatible_groups(reqs: &[TwinRequest]) -> Vec<Vec<usize>> {
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for (i, r) in reqs.iter().enumerate() {
        groups.entry(r.n_points).or_default().push(i);
    }
    groups.into_values().collect()
}

/// The shared scaffolding of a batched `Twin::run_batch` override:
/// split requests into [`compatible_groups`], validate each request with
/// `prepare` (a failure fails that request alone), execute every non-empty
/// group once with `execute` (payloads in submission order + the group's
/// `n_points`), and fan results back out to request order. A group-level
/// error — or an arity mismatch from `execute` — is broadcast to every
/// member of that group without touching the others.
pub fn run_batch_grouped<P>(
    reqs: &[TwinRequest],
    mut prepare: impl FnMut(&TwinRequest) -> anyhow::Result<P>,
    mut execute: impl FnMut(&[P], usize) -> anyhow::Result<Vec<TwinResponse>>,
) -> Vec<anyhow::Result<TwinResponse>> {
    let mut out: Vec<Option<anyhow::Result<TwinResponse>>> = Vec::new();
    out.resize_with(reqs.len(), || None);
    for group in compatible_groups(reqs) {
        let mut members: Vec<usize> = Vec::new();
        let mut payloads: Vec<P> = Vec::new();
        for &i in &group {
            match prepare(&reqs[i]) {
                Ok(p) => {
                    members.push(i);
                    payloads.push(p);
                }
                Err(e) => out[i] = Some(Err(e)),
            }
        }
        if members.is_empty() {
            continue;
        }
        let n_points = reqs[members[0]].n_points;
        let broadcast =
            |out: &mut Vec<Option<anyhow::Result<TwinResponse>>>,
             msg: String| {
                for &i in &members {
                    out[i] = Some(Err(anyhow::anyhow!(msg.clone())));
                }
            };
        match execute(&payloads, n_points) {
            Ok(resps) if resps.len() == members.len() => {
                for (&i, r) in members.iter().zip(resps) {
                    out[i] = Some(Ok(r));
                }
            }
            Ok(resps) => broadcast(
                &mut out,
                format!(
                    "batched backend returned {} responses for {} requests",
                    resps.len(),
                    members.len()
                ),
            ),
            Err(e) => broadcast(&mut out, format!("{e:#}")),
        }
    }
    out.into_iter()
        .map(|o| o.expect("every request receives a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatible_groups_split_by_n_points() {
        let reqs = vec![
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
            TwinRequest::autonomous(vec![], 20),
            TwinRequest::autonomous(vec![], 10),
        ];
        let groups = compatible_groups(&reqs);
        assert_eq!(groups, vec![vec![0, 2, 4], vec![1, 3]]);
        // Every index appears exactly once.
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn default_run_batch_is_serial_fallback() {
        struct Echo;
        impl Twin for Echo {
            fn name(&self) -> &str {
                "echo"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                anyhow::ensure!(req.n_points > 0, "empty request");
                Ok(TwinResponse {
                    trajectory: vec![req.h0.clone(); req.n_points],
                    backend: "echo".into(),
                })
            }
        }
        let mut t = Echo;
        let reqs = vec![
            TwinRequest::autonomous(vec![1.0], 2),
            TwinRequest::autonomous(vec![2.0], 0),
            TwinRequest::autonomous(vec![3.0], 3),
        ];
        let results = t.run_batch(&reqs);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().trajectory.len(), 2);
        assert!(results[1].is_err(), "per-request failure isolated");
        assert_eq!(
            results[2].as_ref().unwrap().trajectory[0],
            vec![3.0]
        );
    }

    #[test]
    fn request_constructors() {
        let r = TwinRequest::autonomous(vec![1.0], 10);
        assert!(r.stimulus.is_none());
        let d = TwinRequest::driven(
            vec![0.1],
            5,
            Waveform::sine(1.0, 4.0),
        );
        assert!(d.stimulus.is_some());
        assert_eq!(d.n_points, 5);
    }
}
