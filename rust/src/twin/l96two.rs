//! Two-level (multi-scale) Lorenz96 twin — the second analytical world in
//! the zoo, exercising a wider state than any trained route (dim 30).
//!
//! K slow variables X_k each drive J fast variables Y_j (Lorenz's 1996
//! two-scale system):
//!
//! ```text
//! dX_k = -X_{k-1}(X_{k-2} - X_{k+1}) - X_k + F - (h c / b) Σ_{j∈J_k} Y_j
//! dY_j = -c b Y_{j+1}(Y_{j+2} - Y_{j-1}) - c Y_j + (h c / b) X_{k(j)}
//! ```
//!
//! State layout: `[X_0 .. X_{K-1}, Y_0 .. Y_{KJ-1}]`, both levels
//! periodic. With the fast level zeroed the slow field reduces exactly to
//! the one-level [`crate::workload::lorenz96`] field — pinned by a test.

use crate::twin::core::{
    CoreBackend, DigitalModel, DynField, DynamicsTwin, StimulusKind,
    TwinSpec,
};
use crate::workload::lorenz96;

/// Slow variables.
pub const K: usize = 6;
/// Fast variables per slow variable.
pub const J: usize = 4;
/// Total state dimension.
pub const DIM: usize = K + K * J;
/// Forcing on the slow level.
pub const FORCING: f64 = 8.0;
/// Coupling strength h.
pub const H: f64 = 1.0;
/// Timescale separation c.
pub const C: f64 = 10.0;
/// Amplitude ratio b.
pub const B: f64 = 10.0;
/// Output sample interval (s) — finer than the one-level twin because
/// the fast level evolves c times quicker.
pub const DT: f64 = 0.01;
/// RK4 substeps per output sample.
const SUBSTEPS: usize = 2;
/// Auto-seed root for noise lanes on this twin.
const L96TWO_AUTO_ROOT: u64 = 0x1962_5eed_0000_0005;

/// Deterministic default initial condition: slow sites near the F = 8
/// attractor, fast sites a small bounded ripple.
pub fn default_y0(k: usize, j: usize) -> Vec<f64> {
    let mut y0 = Vec::with_capacity(k + k * j);
    for i in 0..k {
        y0.push(FORCING + ((i as f64) * 0.9).sin());
    }
    for i in 0..k * j {
        y0.push(0.1 * ((i as f64) * 0.77).cos());
    }
    y0
}

/// The two-level Lorenz96 vector field.
pub struct L96TwoField {
    k: usize,
    j: usize,
}

impl L96TwoField {
    pub fn new(k: usize, j: usize) -> Self {
        assert!(k > 3, "slow level needs K > 3");
        assert!(j > 2, "fast level needs J > 2");
        Self { k, j }
    }
}

impl DynField for L96TwoField {
    fn dim(&self) -> usize {
        self.k + self.k * self.j
    }

    fn eval_into(&self, _t: f64, x: &[f64], out: &mut [f64]) {
        let (k, j) = (self.k, self.j);
        let (xs, ys) = x.split_at(k);
        let (out_x, out_y) = out.split_at_mut(k);
        let hcb = H * C / B;
        for i in 0..k {
            let ip1 = xs[(i + 1) % k];
            let im1 = xs[(i + k - 1) % k];
            let im2 = xs[(i + k - 2) % k];
            let fast_sum: f64 = ys[i * j..(i + 1) * j].iter().sum();
            out_x[i] =
                (ip1 - im2) * im1 - xs[i] + FORCING - hcb * fast_sum;
        }
        let n = k * j;
        for i in 0..n {
            let ip1 = ys[(i + 1) % n];
            let ip2 = ys[(i + 2) % n];
            let im1 = ys[(i + n - 1) % n];
            out_y[i] =
                -C * B * ip1 * (ip2 - im1) - C * ys[i] + hcb * xs[i / j];
        }
    }
}

/// The default registry twin: K = 6 slow, J = 4 fast sites (dim 30).
pub fn twin() -> DynamicsTwin {
    twin_with(K, J)
}

/// A two-level twin with explicit level sizes.
pub fn twin_with(k: usize, j: usize) -> DynamicsTwin {
    let spec = TwinSpec {
        name: "l96two",
        field_label: "l96two/digital",
        dim: k + k * j,
        dt: DT,
        default_h0: default_y0(k, j),
        stimulus: StimulusKind::Autonomous,
        digital_substeps: SUBSTEPS,
    };
    DynamicsTwin::new(
        spec,
        CoreBackend::Digital(DigitalModel::Field(Box::new(
            L96TwoField::new(k, j),
        ))),
        L96TWO_AUTO_ROOT,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{Twin, TwinRequest};

    #[test]
    fn zero_fast_level_reduces_to_one_level_field() {
        let f = L96TwoField::new(6, 4);
        let mut x = vec![0.0; DIM];
        let slow = [1.0, -0.5, 2.0, 0.3, -1.2, 0.8];
        x[..6].copy_from_slice(&slow);
        let mut out = vec![0.0; DIM];
        f.eval_into(0.0, &x, &mut out);
        let mut want = vec![0.0; 6];
        lorenz96::field_into(&slow, FORCING, &mut want);
        for i in 0..6 {
            assert!(
                (out[i] - want[i]).abs() < 1e-12,
                "slow site {i}: {} vs one-level {}",
                out[i],
                want[i]
            );
        }
        // With Y = 0 the fast tendency is pure coupling: (hc/b) X_{k(j)}.
        for i in 0..24 {
            let want = H * C / B * slow[i / 4];
            assert!((out[6 + i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn coupling_feeds_energy_into_the_fast_level() {
        let mut twin = twin();
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 200)).unwrap();
        let last = resp.trajectory.row(199);
        let fast_amp: f64 =
            last[K..].iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(fast_amp > 1e-3, "fast level never excited: {fast_amp}");
    }

    #[test]
    fn trajectory_stays_on_the_attractor() {
        let mut twin = twin();
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 400)).unwrap();
        assert_eq!(resp.trajectory.dim(), DIM);
        for s in 0..resp.trajectory.len() {
            for (i, &v) in resp.trajectory.row(s).iter().enumerate() {
                assert!(v.is_finite(), "sample {s} component {i} diverged");
                let bound = if i < K { 30.0 } else { 15.0 };
                assert!(
                    v.abs() < bound,
                    "sample {s} component {i} escaped: {v}"
                );
            }
        }
    }
}
