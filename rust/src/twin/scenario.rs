//! Scenario DSL: declarative `*.twin` files describing one twin rollout.
//!
//! A scenario names a registry route plus everything needed to reproduce a
//! run — horizon, seed, initial state, stimulus program, ensemble sweep —
//! and optionally a set of expected-envelope assertions that turn the file
//! into an executable acceptance fixture. The format is line-oriented:
//!
//! ```text
//! # Lorenz96 reference rollout (comments run to end of line).
//! twin lorenz96/digital
//! steps 64
//! seed 42
//! y0 2.1 8.0 8.0 8.0 8.0 8.0        # omit to use the twin's default
//! ensemble 16
//! percentiles 10 90
//! expect dim 6
//! expect samples 64
//! expect within -25 25
//! expect final_within -25 25
//! expect mean_abs_below 20
//! ```
//!
//! Driven twins add a stimulus program, e.g. `stimulus sine 1.0 50.0`
//! (kind, amplitude, frequency, and a modulation frequency for
//! `modulated`).
//!
//! Parsing never returns a bare `Err(String)`: every failure is a
//! [`ScenarioError`] carrying the *byte span* of the offending range, and
//! [`ScenarioError::render`] prints a compiler-style diagnostic with the
//! source line and a caret underline. Golden tests pin the exact spans
//! (`rust/tests/scenarios.rs`), and every committed
//! `examples/scenarios/*.twin` round-trips through the synthetic registry.

use crate::twin::{EnsembleSpec, TwinRequest, TwinResponse};
use crate::workload::stimuli::Waveform;

/// Half-open byte range `[start, end)` into the scenario source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub end: usize,
}

impl Span {
    pub fn new(start: usize, end: usize) -> Self {
        Self { start, end }
    }
}

/// A parse failure pointing at the offending byte range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioError {
    pub message: String,
    pub span: Span,
}

impl ScenarioError {
    fn new(span: Span, message: impl Into<String>) -> Self {
        Self { message: message.into(), span }
    }

    /// Render a compiler-style diagnostic against the source text:
    ///
    /// ```text
    /// error: unknown directive 'stims'
    ///  --> fixtures/bad.twin:3:1
    ///  |
    /// 3 | stims sine 1.0 4.0
    ///  | ^^^^^
    /// ```
    pub fn render(&self, src: &str, origin: &str) -> String {
        let mut line_start = 0usize;
        let mut line_no = 1usize;
        let mut line_text = "";
        for (n, raw) in src.split('\n').enumerate() {
            let end = line_start + raw.len();
            if self.span.start <= end {
                line_no = n + 1;
                line_text = raw;
                break;
            }
            line_start = end + 1;
        }
        let col = self.span.start.saturating_sub(line_start);
        let width = self
            .span
            .end
            .saturating_sub(self.span.start)
            .clamp(1, line_text.len().saturating_sub(col).max(1));
        let gutter = format!("{line_no}").len();
        let pad = " ".repeat(gutter);
        let carets = format!("{}{}", " ".repeat(col), "^".repeat(width));
        format!(
            "error: {}\n{} --> {}:{}:{}\n{} |\n{} | {}\n{} | {}",
            self.message,
            pad,
            origin,
            line_no,
            col + 1,
            pad,
            line_no,
            line_text,
            pad,
            carets
        )
    }
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (bytes {}..{})",
            self.message, self.span.start, self.span.end
        )
    }
}

impl std::error::Error for ScenarioError {}

/// One expected-envelope assertion from an `expect` directive.
#[derive(Debug, Clone, PartialEq)]
pub enum Expectation {
    /// `expect dim N` — response state dimension.
    Dim(usize),
    /// `expect samples N` — trajectory length.
    Samples(usize),
    /// `expect within LO HI` — every sample of every component in range.
    Within(f64, f64),
    /// `expect final_within LO HI` — every component of the last sample.
    FinalWithin(f64, f64),
    /// `expect mean_abs_below X` — mean |sample| across the trajectory.
    MeanAbsBelow(f64),
}

/// A parsed scenario: the declarative description of one twin rollout.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Registry route, e.g. `lorenz96/digital`.
    pub twin: String,
    /// Output samples to produce.
    pub steps: usize,
    /// Replay seed; `None` lets the twin auto-derive one.
    pub seed: Option<u64>,
    /// Initial state; empty means the twin's default.
    pub y0: Vec<f64>,
    /// Stimulus program for driven twins.
    pub stimulus: Option<Waveform>,
    /// Ensemble sweep size (1 lane when absent).
    pub ensemble: Option<usize>,
    /// Percentile bands for the ensemble sweep.
    pub percentiles: Vec<f64>,
    /// Expected-envelope assertions.
    pub expectations: Vec<Expectation>,
}

#[derive(Clone, Copy)]
struct Tok<'a> {
    text: &'a str,
    span: Span,
}

fn tokens(line: &str, base: usize) -> Vec<Tok<'_>> {
    let mut out = Vec::new();
    let mut start = None;
    for (i, c) in line.char_indices() {
        if c.is_whitespace() {
            if let Some(s) = start.take() {
                out.push(Tok {
                    text: &line[s..i],
                    span: Span::new(base + s, base + i),
                });
            }
        } else if start.is_none() {
            start = Some(i);
        }
    }
    if let Some(s) = start {
        out.push(Tok {
            text: &line[s..],
            span: Span::new(base + s, base + line.len()),
        });
    }
    out
}

fn args_span(dir: &Tok<'_>, args: &[Tok<'_>]) -> Span {
    match (args.first(), args.last()) {
        (Some(a), Some(b)) => Span::new(a.span.start, b.span.end),
        _ => dir.span,
    }
}

fn parse_f64(tok: &Tok<'_>) -> Result<f64, ScenarioError> {
    tok.text.parse().map_err(|_| {
        ScenarioError::new(
            tok.span,
            format!("expected a number, found '{}'", tok.text),
        )
    })
}

fn parse_usize(tok: &Tok<'_>) -> Result<usize, ScenarioError> {
    tok.text.parse().map_err(|_| {
        ScenarioError::new(
            tok.span,
            format!("expected a non-negative integer, found '{}'", tok.text),
        )
    })
}

fn parse_u64(tok: &Tok<'_>) -> Result<u64, ScenarioError> {
    tok.text.parse().map_err(|_| {
        ScenarioError::new(
            tok.span,
            format!("expected an unsigned integer, found '{}'", tok.text),
        )
    })
}

fn expect_args<'a>(
    dir: &Tok<'a>,
    args: &'a [Tok<'a>],
    n: usize,
    usage: &str,
) -> Result<&'a [Tok<'a>], ScenarioError> {
    if args.len() < n {
        return Err(ScenarioError::new(
            dir.span,
            format!("'{}' expects {usage}", dir.text),
        ));
    }
    if args.len() > n {
        return Err(ScenarioError::new(
            args_span(dir, &args[n..]),
            format!("'{}' expects {usage} (extra arguments)", dir.text),
        ));
    }
    Ok(args)
}

fn reject_duplicate(
    seen: &mut Option<Span>,
    dir: &Tok<'_>,
) -> Result<(), ScenarioError> {
    if seen.is_some() {
        return Err(ScenarioError::new(
            dir.span,
            format!("duplicate '{}' directive", dir.text),
        ));
    }
    *seen = Some(dir.span);
    Ok(())
}

impl Scenario {
    /// Parse scenario source text. On failure the error's span points at
    /// the offending byte range of `src`.
    pub fn parse(src: &str) -> Result<Self, ScenarioError> {
        let mut twin: Option<String> = None;
        let mut twin_seen = None;
        let mut steps: Option<usize> = None;
        let mut steps_seen = None;
        let mut seed: Option<u64> = None;
        let mut seed_seen = None;
        let mut y0: Vec<f64> = Vec::new();
        let mut y0_seen = None;
        let mut stimulus: Option<Waveform> = None;
        let mut stimulus_seen = None;
        let mut ensemble: Option<usize> = None;
        let mut ensemble_seen = None;
        let mut percentiles: Vec<f64> = Vec::new();
        let mut percentiles_seen: Option<Span> = None;
        let mut expectations = Vec::new();

        let mut offset = 0usize;
        for raw in src.split('\n') {
            let line_start = offset;
            offset += raw.len() + 1;
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            };
            let toks = tokens(line, line_start);
            let Some((dir, args)) = toks.split_first() else {
                continue;
            };
            match dir.text {
                "twin" => {
                    reject_duplicate(&mut twin_seen, dir)?;
                    let a = expect_args(dir, args, 1, "one route argument")?;
                    if !a[0].text.contains('/') {
                        return Err(ScenarioError::new(
                            a[0].span,
                            format!(
                                "route '{}' is not of the form \
                                 family/backend",
                                a[0].text
                            ),
                        ));
                    }
                    twin = Some(a[0].text.to_string());
                }
                "steps" => {
                    reject_duplicate(&mut steps_seen, dir)?;
                    let a =
                        expect_args(dir, args, 1, "one integer argument")?;
                    let n = parse_usize(&a[0])?;
                    if n == 0 {
                        return Err(ScenarioError::new(
                            a[0].span,
                            "steps must be at least 1",
                        ));
                    }
                    steps = Some(n);
                }
                "seed" => {
                    reject_duplicate(&mut seed_seen, dir)?;
                    let a =
                        expect_args(dir, args, 1, "one integer argument")?;
                    seed = Some(parse_u64(&a[0])?);
                }
                "y0" => {
                    reject_duplicate(&mut y0_seen, dir)?;
                    if args.is_empty() {
                        return Err(ScenarioError::new(
                            dir.span,
                            "'y0' expects at least one number \
                             (omit the directive for the twin default)",
                        ));
                    }
                    for tok in args {
                        y0.push(parse_f64(tok)?);
                    }
                }
                "stimulus" => {
                    reject_duplicate(&mut stimulus_seen, dir)?;
                    if args.is_empty() {
                        return Err(ScenarioError::new(
                            dir.span,
                            "'stimulus' expects a waveform kind \
                             (sine|triangular|rectangular|modulated)",
                        ));
                    }
                    let kind = &args[0];
                    let rest = &args[1..];
                    stimulus = Some(match kind.text {
                        "sine" => {
                            let a = expect_args(
                                kind,
                                rest,
                                2,
                                "amplitude and frequency",
                            )?;
                            Waveform::sine(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                            )
                        }
                        "triangular" => {
                            let a = expect_args(
                                kind,
                                rest,
                                2,
                                "amplitude and frequency",
                            )?;
                            Waveform::triangular(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                            )
                        }
                        "rectangular" => {
                            let a = expect_args(
                                kind,
                                rest,
                                2,
                                "amplitude and frequency",
                            )?;
                            Waveform::rectangular(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                            )
                        }
                        "modulated" => {
                            let a = expect_args(
                                kind,
                                rest,
                                3,
                                "amplitude, frequency and \
                                 modulation frequency",
                            )?;
                            Waveform::modulated(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                                parse_f64(&a[2])?,
                            )
                        }
                        other => {
                            return Err(ScenarioError::new(
                                kind.span,
                                format!(
                                    "unknown waveform '{other}' (expected \
                                     sine|triangular|rectangular|\
                                     modulated)"
                                ),
                            ));
                        }
                    });
                }
                "ensemble" => {
                    reject_duplicate(&mut ensemble_seen, dir)?;
                    let a =
                        expect_args(dir, args, 1, "one integer argument")?;
                    let n = parse_usize(&a[0])?;
                    if n == 0 {
                        return Err(ScenarioError::new(
                            a[0].span,
                            "ensemble must have at least 1 member",
                        ));
                    }
                    ensemble = Some(n);
                }
                "percentiles" => {
                    reject_duplicate(&mut percentiles_seen, dir)?;
                    if args.is_empty() {
                        return Err(ScenarioError::new(
                            dir.span,
                            "'percentiles' expects at least one number",
                        ));
                    }
                    for tok in args {
                        let p = parse_f64(tok)?;
                        if !(0.0..=100.0).contains(&p) {
                            return Err(ScenarioError::new(
                                tok.span,
                                format!(
                                    "percentile {p} outside 0..=100"
                                ),
                            ));
                        }
                        percentiles.push(p);
                    }
                }
                "expect" => {
                    if args.is_empty() {
                        return Err(ScenarioError::new(
                            dir.span,
                            "'expect' needs an assertion kind (dim|\
                             samples|within|final_within|mean_abs_below)",
                        ));
                    }
                    let kind = &args[0];
                    let rest = &args[1..];
                    expectations.push(match kind.text {
                        "dim" => {
                            let a = expect_args(
                                kind,
                                rest,
                                1,
                                "one integer argument",
                            )?;
                            Expectation::Dim(parse_usize(&a[0])?)
                        }
                        "samples" => {
                            let a = expect_args(
                                kind,
                                rest,
                                1,
                                "one integer argument",
                            )?;
                            Expectation::Samples(parse_usize(&a[0])?)
                        }
                        "within" => {
                            let a = expect_args(
                                kind,
                                rest,
                                2,
                                "a low and a high bound",
                            )?;
                            Expectation::Within(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                            )
                        }
                        "final_within" => {
                            let a = expect_args(
                                kind,
                                rest,
                                2,
                                "a low and a high bound",
                            )?;
                            Expectation::FinalWithin(
                                parse_f64(&a[0])?,
                                parse_f64(&a[1])?,
                            )
                        }
                        "mean_abs_below" => {
                            let a = expect_args(
                                kind,
                                rest,
                                1,
                                "one numeric bound",
                            )?;
                            Expectation::MeanAbsBelow(parse_f64(&a[0])?)
                        }
                        other => {
                            return Err(ScenarioError::new(
                                kind.span,
                                format!(
                                    "unknown expectation '{other}' \
                                     (expected dim|samples|within|\
                                     final_within|mean_abs_below)"
                                ),
                            ));
                        }
                    });
                }
                other => {
                    return Err(ScenarioError::new(
                        dir.span,
                        format!("unknown directive '{other}'"),
                    ));
                }
            }
        }

        let twin = twin.ok_or_else(|| {
            ScenarioError::new(
                Span::new(0, 0),
                "missing required 'twin' directive",
            )
        })?;
        let steps = steps.ok_or_else(|| {
            ScenarioError::new(
                Span::new(0, 0),
                "missing required 'steps' directive",
            )
        })?;
        if let (Some(span), None) = (percentiles_seen, ensemble) {
            return Err(ScenarioError::new(
                span,
                "'percentiles' requires an 'ensemble' directive",
            ));
        }

        Ok(Self {
            twin,
            steps,
            seed,
            y0,
            stimulus,
            ensemble,
            percentiles,
            expectations,
        })
    }

    /// Build the [`TwinRequest`] this scenario describes.
    pub fn to_request(&self) -> TwinRequest {
        let mut req = match self.stimulus {
            Some(wave) => {
                TwinRequest::driven(self.y0.clone(), self.steps, wave)
            }
            None => TwinRequest::autonomous(self.y0.clone(), self.steps),
        };
        if let Some(seed) = self.seed {
            req = req.with_seed(seed);
        }
        if let Some(members) = self.ensemble {
            let mut spec = EnsembleSpec::new(members);
            if !self.percentiles.is_empty() {
                spec = spec.with_percentiles(self.percentiles.clone());
            }
            req = req.with_ensemble(spec);
        }
        req
    }

    /// Evaluate every `expect` assertion against a response. Returns the
    /// list of violated assertions (empty = all pass).
    pub fn check(&self, resp: &TwinResponse) -> Vec<String> {
        let traj = &resp.trajectory;
        let mut failures = Vec::new();
        for exp in &self.expectations {
            match *exp {
                Expectation::Dim(want) => {
                    if traj.dim() != want {
                        failures.push(format!(
                            "expect dim {want}: response dim is {}",
                            traj.dim()
                        ));
                    }
                }
                Expectation::Samples(want) => {
                    if traj.len() != want {
                        failures.push(format!(
                            "expect samples {want}: response has {} \
                             samples",
                            traj.len()
                        ));
                    }
                }
                Expectation::Within(lo, hi) => {
                    let bad = (0..traj.len())
                        .flat_map(|i| traj.row(i).iter().copied())
                        .find(|v| !(lo..=hi).contains(v));
                    if let Some(v) = bad {
                        failures.push(format!(
                            "expect within {lo} {hi}: sample {v} escapes \
                             the envelope"
                        ));
                    }
                }
                Expectation::FinalWithin(lo, hi) => {
                    let bad = traj
                        .last()
                        .into_iter()
                        .flat_map(|row| row.iter().copied())
                        .find(|v| !(lo..=hi).contains(v));
                    if let Some(v) = bad {
                        failures.push(format!(
                            "expect final_within {lo} {hi}: final \
                             component {v} escapes the envelope"
                        ));
                    }
                }
                Expectation::MeanAbsBelow(bound) => {
                    let mut sum = 0.0;
                    let mut count = 0usize;
                    for i in 0..traj.len() {
                        for v in traj.row(i) {
                            sum += v.abs();
                            count += 1;
                        }
                    }
                    let mean = if count == 0 { 0.0 } else { sum / count as f64 };
                    // NaN means also fail the envelope, so compare via
                    // the negation rather than `mean >= bound`.
                    let passes = mean < bound;
                    if !passes {
                        failures.push(format!(
                            "expect mean_abs_below {bound}: mean |x| is \
                             {mean}"
                        ));
                    }
                }
            }
        }
        failures
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Trajectory;

    const GOOD: &str = "\
# reference rollout
twin lorenz96/digital
steps 16
seed 42
y0 2.1 8.0 8.0 8.0 8.0 8.0
ensemble 4
percentiles 10 90
expect dim 6
expect samples 16
expect within -30 30
";

    #[test]
    fn parses_a_full_scenario() {
        let s = Scenario::parse(GOOD).unwrap();
        assert_eq!(s.twin, "lorenz96/digital");
        assert_eq!(s.steps, 16);
        assert_eq!(s.seed, Some(42));
        assert_eq!(s.y0.len(), 6);
        assert!(s.stimulus.is_none());
        assert_eq!(s.ensemble, Some(4));
        assert_eq!(s.percentiles, vec![10.0, 90.0]);
        assert_eq!(s.expectations.len(), 3);
        let req = s.to_request();
        assert_eq!(req.n_points, 16);
        assert_eq!(req.seed, Some(42));
        assert_eq!(req.lanes(), 4);
    }

    #[test]
    fn driven_scenario_builds_a_driven_request() {
        let s = Scenario::parse(
            "twin hp/digital\nsteps 8\nstimulus sine 1.0 50.0\n",
        )
        .unwrap();
        let wave = s.stimulus.expect("stimulus parsed");
        assert_eq!(wave, Waveform::sine(1.0, 50.0));
        assert!(s.to_request().stimulus.is_some());
    }

    #[test]
    fn unknown_directive_spans_the_token() {
        let src = "twin hp/digital\nsteps 8\nstims sine 1.0 4.0\n";
        let err = Scenario::parse(src).unwrap_err();
        assert_eq!(err.span, Span::new(24, 29));
        assert_eq!(&src[err.span.start..err.span.end], "stims");
        let pretty = err.render(src, "bad.twin");
        assert!(pretty.contains("bad.twin:3:1"), "{pretty}");
        assert!(pretty.contains("^^^^^"), "{pretty}");
    }

    #[test]
    fn bad_number_spans_the_argument() {
        let src = "twin hp/digital\nsteps eight\n";
        let err = Scenario::parse(src).unwrap_err();
        assert_eq!(&src[err.span.start..err.span.end], "eight");
    }

    #[test]
    fn duplicate_directive_is_rejected() {
        let src = "twin hp/digital\nsteps 4\ntwin hp/analog\n";
        let err = Scenario::parse(src).unwrap_err();
        assert!(err.message.contains("duplicate 'twin'"), "{err}");
        assert_eq!(&src[err.span.start..err.span.end], "twin");
        assert_eq!(err.span.start, 24);
    }

    #[test]
    fn missing_twin_is_reported() {
        let err = Scenario::parse("steps 4\n").unwrap_err();
        assert!(err.message.contains("missing required 'twin'"));
    }

    #[test]
    fn percentiles_require_ensemble() {
        let src = "twin a/b\nsteps 4\npercentiles 10 90\n";
        let err = Scenario::parse(src).unwrap_err();
        assert!(err.message.contains("requires an 'ensemble'"), "{err}");
        assert_eq!(&src[err.span.start..err.span.end], "percentiles");
    }

    #[test]
    fn expectations_flag_envelope_escapes() {
        let s = Scenario::parse(
            "twin a/b\nsteps 2\nexpect dim 1\nexpect samples 2\n\
             expect within -1 1\nexpect final_within -1 1\n\
             expect mean_abs_below 0.5\n",
        )
        .unwrap();
        let ok = TwinResponse {
            trajectory: Trajectory::from_data(1, vec![0.1, 0.2]),
            backend: "digital-rk4",
            seed: 0,
            ensemble: None,
            degraded: false,
        };
        assert!(s.check(&ok).is_empty());
        let bad = TwinResponse {
            trajectory: Trajectory::from_data(1, vec![0.1, 3.0]),
            backend: "digital-rk4",
            seed: 0,
            ensemble: None,
            degraded: false,
        };
        let failures = s.check(&bad);
        assert_eq!(failures.len(), 3, "{failures:?}");
    }
}
