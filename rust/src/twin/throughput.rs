//! Serial-vs-batched twin throughput measurement — shared by the
//! `batch_throughput` bench binary (full mode, release) and the tier-1
//! smoke test (`rust/tests/bench_smoke.rs`), both of which emit the
//! machine-readable `BENCH_batch_throughput.json` at the repository root
//! so the perf trajectory is tracked from PR 2 onward.
//!
//! The metric is **ns per trajectory-step**: wall time divided by
//! `batch * n_points`, i.e. the cost of producing one output sample of one
//! trajectory. Batched wins come from amortising the weight-matrix
//! traversal, the moment-matched variance GEMM and per-request overhead
//! across the batch; the speedup column is `serial / batched` at equal work.

use std::path::PathBuf;

use crate::analog::system::AnalogNoise;
use crate::device::taox::DeviceConfig;
use crate::models::loader::MlpWeights;
use crate::twin::hp::HpTwin;
use crate::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};
use crate::twin::{Twin, TwinRequest};
use crate::util::bench::Bencher;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;
use crate::workload::stimuli::Waveform;

/// One measured (route, batch size) cell.
#[derive(Debug, Clone)]
pub struct ThroughputEntry {
    pub route: &'static str,
    pub batch: usize,
    pub n_points: usize,
    /// Median ns per trajectory-step, B serial `run` calls.
    pub serial_ns_per_step: f64,
    /// Median ns per trajectory-step, one `run_batch` call.
    pub batched_ns_per_step: f64,
    /// serial / batched (per-step; > 1 means batching wins).
    pub speedup: f64,
}

/// The measured routes: HP and Lorenz96 (analogue + digital backends),
/// plus the wide Lorenz96 pair tracking sharded-vs-monolithic execution —
/// `l96d64/analog` runs the d = 64 state as one monolithic rollout,
/// `l96d64/analog-shard2` the *same deployment* fanned out across two
/// tile-shard workers. Comparing the two routes' ns/trajectory-step (same
/// B, same column) is the tracked sharding overhead/benefit.
/// `l96d64/analog-ens32` submits 32-member Monte-Carlo ensemble requests
/// on the monolithic d = 64 deployment: its "serial" column is one
/// 32-lane ensemble rollout per request, its "batched" column coalesces B
/// requests into one (B * 32)-lane rollout — the tracked cost of
/// first-class ensembles.
/// `l96d64/analog-aged` is the same monolithic deployment on a *mortal*
/// crossbar ([`Lorenz96Twin::analog_aging`]): comparing it against
/// `l96d64/analog` at equal B tracks the lifetime bookkeeping's hot-path
/// overhead — which must stay ~zero, since aging only mutates cached
/// conductances at `advance_age` time, never per read.
/// `kuramoto/digital` and `l96two/digital` are the zoo's closed-form
/// analytic worlds on the generic core's `DynField` digital path — their
/// rows track the shared request-execution machinery at dims 16 and 30.
pub const ROUTES: [&str; 10] = [
    "hp/analog",
    "hp/digital",
    "l96/analog",
    "l96/digital",
    "l96d64/analog",
    "l96d64/analog-shard2",
    "l96d64/analog-ens32",
    "l96d64/analog-aged",
    "kuramoto/digital",
    "l96two/digital",
];

/// Circuit substeps for the d = 64 routes (smaller than the paper-default
/// 20 so the smoke bench stays within tier-1 budget; identical for the
/// monolithic and sharded rows, so the comparison is apples-to-apples).
pub const D64_SUBSTEPS: usize = 5;

/// Ensemble width of the `*-ens32` route.
pub const ENS_BENCH_MEMBERS: usize = 32;

/// Lane budget of one ensemble-route measurement cell: B requests expand
/// to `B * ENS_BENCH_MEMBERS` lanes, so wider batch sizes (the full
/// bench's B = 128) are skipped — loudly, never silently — to keep one
/// cell's rollout under this many trajectories.
pub const MAX_ENS_BENCH_LANES: usize = 1024;

fn synth_mlp(
    dims: &[(usize, usize)],
    dt: f64,
    task: &str,
    seed: u64,
) -> MlpWeights {
    let mut rng = Pcg64::seeded(seed);
    let layers = dims
        .iter()
        .map(|&(r, c)| {
            (
                Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.2, 0.2)),
                (0..c).map(|_| rng.uniform_in(-0.05, 0.05)).collect(),
            )
        })
        .collect();
    MlpWeights { layers, dt, kind: "node".into(), task: task.into() }
}

/// Trained-shape HP field: [v; h] -> 14 -> 14 -> 1 (the timing-relevant
/// structure of the real hp_node artifact).
pub fn hp_weights() -> MlpWeights {
    synth_mlp(&[(2, 14), (14, 14), (14, 1)], 1e-3, "hp", 17)
}

/// Trained-shape Lorenz96 field: 6 -> 64 -> 64 -> 6 with pseudo-random
/// weights (the timing-relevant structure of the real l96_node artifact).
pub fn l96_weights() -> MlpWeights {
    synth_mlp(&[(6, 64), (64, 64), (64, 6)], 0.02, "l96", 42)
}

/// Wide Lorenz96 field: a d = 64 state (two physical tile column-groups)
/// with one 64-wide hidden layer — the "state larger than one array"
/// scenario the sharded execution path exists for.
pub fn l96d64_weights() -> MlpWeights {
    synth_mlp(&[(64, 64), (64, 64)], 0.02, "l96", 77)
}

/// Per-route state dimension of the autonomous routes.
fn route_dim(route: &str) -> usize {
    if route.starts_with("l96d64/") {
        64
    } else if route.starts_with("kuramoto/") {
        crate::twin::kuramoto::DIM
    } else if route.starts_with("l96two/") {
        crate::twin::l96two::DIM
    } else {
        6
    }
}

fn d64_opts(shards: usize, parallel: bool) -> L96AnalogOpts {
    L96AnalogOpts { substeps: D64_SUBSTEPS, shards, parallel }
}

/// Build the twin behind a measured route, at the paper's hardware noise
/// operating point for the analogue backends.
pub fn make_twin(route: &str) -> Box<dyn Twin> {
    let device = DeviceConfig { fault_rate: 0.0, ..Default::default() };
    match route {
        "hp/analog" => Box::new(HpTwin::analog(
            &hp_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
        )),
        "hp/digital" => Box::new(HpTwin::digital(&hp_weights())),
        "l96/analog" => Box::new(Lorenz96Twin::analog(
            &l96_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
        )),
        "l96/digital" => Box::new(Lorenz96Twin::digital(&l96_weights())),
        "l96d64/analog" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
            d64_opts(1, false),
        )),
        "l96d64/analog-shard2" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
            d64_opts(2, true),
        )),
        // Same monolithic d = 64 deployment; the ensemble lives in the
        // *requests* (see `requests`), not the twin.
        "l96d64/analog-ens32" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
            d64_opts(1, false),
        )),
        "l96d64/analog-aged" => Box::new(Lorenz96Twin::analog_aging(
            &l96d64_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
            D64_SUBSTEPS,
        )),
        "kuramoto/digital" => Box::new(crate::twin::kuramoto::twin()),
        "l96two/digital" => Box::new(crate::twin::l96two::twin()),
        other => panic!("unknown throughput route '{other}'"),
    }
}

/// Noise-free variant of a route's twin (for bit-identity gates).
pub fn make_quiet_twin(route: &str) -> Box<dyn Twin> {
    let quiet = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    };
    match route {
        "hp/analog" => Box::new(HpTwin::analog(
            &hp_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
        )),
        "l96/analog" => Box::new(Lorenz96Twin::analog(
            &l96_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
        )),
        "l96d64/analog" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
            d64_opts(1, false),
        )),
        "l96d64/analog-shard2" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
            d64_opts(2, true),
        )),
        "l96d64/analog-ens32" => Box::new(Lorenz96Twin::analog_opts(
            &l96d64_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
            d64_opts(1, false),
        )),
        "l96d64/analog-aged" => Box::new(Lorenz96Twin::analog_aging(
            &l96d64_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
            D64_SUBSTEPS,
        )),
        other => make_twin(other),
    }
}

/// Deterministic request batch for a route (driven for HP, autonomous for
/// Lorenz96; per-request stimuli / initial states differ; `*-ens32`
/// routes carry a 32-member ensemble spec per request).
pub fn requests(route: &str, b: usize, n_points: usize) -> Vec<TwinRequest> {
    let mut rng = Pcg64::seeded(7);
    let waves = [
        Waveform::sine(1.0, 4.0),
        Waveform::triangular(1.0, 4.0),
        Waveform::rectangular(1.0, 4.0),
        Waveform::modulated(1.0, 4.0, 1.0),
    ];
    let dim = route_dim(route);
    (0..b)
        .map(|k| {
            let req = if route.starts_with("hp/") {
                TwinRequest::driven(
                    vec![rng.uniform_in(0.1, 0.9)],
                    n_points,
                    waves[k % waves.len()],
                )
            } else {
                TwinRequest::autonomous(
                    (0..dim).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                    n_points,
                )
            };
            if route.ends_with("-ens32") {
                req.with_ensemble(
                    crate::twin::EnsembleSpec::new(ENS_BENCH_MEMBERS),
                )
            } else {
                req
            }
        })
        .collect()
}

/// Assert the sharded d = 64 route reproduces the monolithic route
/// bit-for-bit under noise-off deployment — per request, for both the
/// serial `run` and the batched `run_batch` paths. Sharding must never buy
/// capacity with accuracy drift.
pub fn assert_sharded_matches_monolithic(b: usize, n_points: usize) {
    let mut mono = make_quiet_twin("l96d64/analog");
    let mut sharded = make_quiet_twin("l96d64/analog-shard2");
    let reqs = requests("l96d64/analog", b, n_points);
    for (k, r) in reqs.iter().enumerate() {
        let a = mono.run(r).unwrap();
        let s = sharded.run(r).unwrap();
        assert_eq!(
            a.trajectory, s.trajectory,
            "request {k}: sharded serial rollout != monolithic"
        );
    }
    let am = mono.run_batch(&reqs);
    let ash = sharded.run_batch(&reqs);
    for (k, (a, s)) in am.iter().zip(&ash).enumerate() {
        assert_eq!(
            a.as_ref().unwrap().trajectory,
            s.as_ref().unwrap().trajectory,
            "request {k}: sharded batched rollout != monolithic"
        );
    }
}

/// Assert `run_batch` reproduces per-request `run` bit-for-bit on a
/// noise-free twin (speed never buys accuracy drift).
pub fn assert_bit_identical(route: &str, b: usize, n_points: usize) {
    let mut twin = make_quiet_twin(route);
    let reqs = requests(route, b, n_points);
    let serial: Vec<_> =
        reqs.iter().map(|r| twin.run(r).unwrap()).collect();
    let batched = twin.run_batch(&reqs);
    for (k, (got, want)) in batched.iter().zip(&serial).enumerate() {
        assert_eq!(
            got.as_ref().unwrap().trajectory,
            want.trajectory,
            "{route} request {k}: batched != serial under noise-off"
        );
    }
}

/// Measure one route at the given batch sizes. Ensemble routes skip
/// batch sizes whose lane total would exceed [`MAX_ENS_BENCH_LANES`]
/// (announced on stdout, so the coverage cut is never silent), and their
/// per-step normaliser counts *lanes* — every member is a real rollout
/// trajectory — keeping the ns/trajectory-step unit comparable across
/// rows.
pub fn measure_route(
    route: &'static str,
    batch_sizes: &[usize],
    n_points: usize,
    bench: &Bencher,
) -> Vec<ThroughputEntry> {
    let mut twin = make_twin(route);
    let mut entries = Vec::new();
    for &b in batch_sizes {
        let lanes_per_req =
            if route.ends_with("-ens32") { ENS_BENCH_MEMBERS } else { 1 };
        if b * lanes_per_req > MAX_ENS_BENCH_LANES {
            println!(
                "skipping {route} B={b}: {} lanes exceeds the ensemble \
                 bench budget of {MAX_ENS_BENCH_LANES}",
                b * lanes_per_req
            );
            continue;
        }
        let reqs = requests(route, b, n_points);
        let steps = (b * lanes_per_req * n_points) as f64;
        let serial = bench.run(&format!("{route} serial x{b}"), || {
            let mut n_ok = 0;
            for r in &reqs {
                n_ok += twin.run(r).unwrap().trajectory.len();
            }
            n_ok
        });
        let batched = bench.run(&format!("{route} run_batch B={b}"), || {
            let results = twin.run_batch(&reqs);
            assert!(results.iter().all(|r| r.is_ok()));
            results.len()
        });
        let serial_ns = serial.median.as_nanos() as f64 / steps;
        let batched_ns = batched.median.as_nanos() as f64 / steps;
        entries.push(ThroughputEntry {
            route,
            batch: b,
            n_points,
            serial_ns_per_step: serial_ns,
            batched_ns_per_step: batched_ns,
            speedup: serial_ns / batched_ns.max(1e-9),
        });
    }
    entries
}

/// Measure every route in [`ROUTES`].
pub fn measure(
    batch_sizes: &[usize],
    n_points: usize,
    bench: &Bencher,
) -> Vec<ThroughputEntry> {
    ROUTES
        .iter()
        .flat_map(|&r| measure_route(r, batch_sizes, n_points, bench))
        .collect()
}

/// Serialise entries to the tracked-benchmark JSON document.
pub fn to_json(mode: &str, entries: &[ThroughputEntry]) -> Json {
    let rows = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("route", Json::Str(e.route.to_string())),
                ("batch", Json::Num(e.batch as f64)),
                ("n_points", Json::Num(e.n_points as f64)),
                ("serial_ns_per_step", Json::Num(e.serial_ns_per_step)),
                ("batched_ns_per_step", Json::Num(e.batched_ns_per_step)),
                ("speedup", Json::Num(e.speedup)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("batch_throughput".into())),
        ("mode", Json::Str(mode.into())),
        ("unit", Json::Str("ns_per_trajectory_step".into())),
        ("entries", Json::Arr(rows)),
    ])
}

/// Where the tracked benchmark lands: `$BENCH_OUT` if set, else
/// `BENCH_batch_throughput.json` at the repository root.
pub fn default_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_OUT") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batch_throughput.json")
}

/// Write the benchmark JSON.
pub fn write_json(
    path: &std::path::Path,
    mode: &str,
    entries: &[ThroughputEntry],
) -> anyhow::Result<()> {
    crate::util::json::to_file(path, &to_json(mode, entries))
}

// ---------------------------------------------------------------------------
// Bench-regression gate
// ---------------------------------------------------------------------------

/// Where the committed baseline lives: `$BENCH_BASELINE` if set, else
/// `BENCH_baseline.json` at the repository root (tracked in git, unlike
/// the machine-local `BENCH_batch_throughput.json`).
pub fn default_baseline_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_BASELINE") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_baseline.json")
}

/// Outcome of [`gate_against_baseline`].
#[derive(Debug)]
pub struct GateReport {
    /// (route, batch) metric pairs present in both documents.
    pub compared: usize,
    /// Median fresh/baseline ratio — the machine-speed normaliser.
    pub scale: f64,
    /// Human-readable descriptions of every tracked metric whose
    /// normalised ratio exceeded the allowance.
    pub failures: Vec<String>,
    /// Tracked metrics that *improved* beyond the allowance (normalised
    /// ratio below `scale / (1 + max_regress)`) — the ratchet signal: a
    /// kernel-level speedup shows up here, and `bench_gate --ratchet`
    /// rewrites the baseline so the gate measures future regressions
    /// from the new, faster level.
    pub improvements: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// True when the baseline carried no entries. The gate binary treats
    /// this as a hard failure unless explicitly bootstrapping
    /// (`--allow-unseeded`) — an unseeded baseline protects nothing.
    pub fn unseeded(&self) -> bool {
        self.compared == 0
    }

    /// True when at least one tracked metric improved beyond the
    /// allowance (see [`GateReport::improvements`]).
    pub fn improved(&self) -> bool {
        !self.improvements.is_empty()
    }
}

/// Flatten a benchmark document into ((route, batch), serial, batched)
/// rows.
fn bench_rows(doc: &Json) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let arr = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("benchmark json has no entries"))?;
    arr.iter()
        .map(|e| {
            let route = e
                .get("route")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("entry without route"))?;
            let batch = e
                .get("batch")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("entry without batch"))?;
            let serial = e
                .get("serial_ns_per_step")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("entry without serial ns"))?;
            let batched = e
                .get("batched_ns_per_step")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("entry without batched ns"))?;
            Ok((format!("{route} B={batch}"), serial, batched))
        })
        .collect()
}

/// Compare a fresh smoke benchmark against the committed baseline: fail
/// any tracked route whose ns/trajectory-step regressed by more than
/// `max_regress` (fraction, e.g. 0.25) *after normalising out uniform
/// machine-speed differences*.
///
/// Normalisation: CI machines differ in absolute speed run to run, so raw
/// ns comparisons would be pure noise. Instead the gate computes every
/// (route, batch, serial|batched) fresh/baseline ratio, takes the median
/// ratio as the machine-speed scale, and flags metrics whose ratio exceeds
/// `scale * (1 + max_regress)`. A *uniform* slowdown therefore passes (by
/// design — it is indistinguishable from a slower runner), while any route
/// that regressed *relative to the rest of the suite* fails. An empty or
/// missing baseline returns an empty report with [`GateReport::unseeded`]
/// set — the `bench_gate` binary treats that as a hard failure (unless
/// bootstrapping with `--allow-unseeded`) and seeds/ratchets the baseline
/// in `--ratchet` mode.
pub fn gate_against_baseline(
    baseline: &Json,
    fresh: &Json,
    max_regress: f64,
) -> anyhow::Result<GateReport> {
    let base = bench_rows(baseline)?;
    let new = bench_rows(fresh)?;
    let mut pairs: Vec<(String, f64, f64)> = Vec::new();
    for (key, bs, bb) in &base {
        if let Some((_, ns, nb)) = new.iter().find(|(k, _, _)| k == key) {
            if *bs > 0.0 && *ns > 0.0 {
                pairs.push((format!("{key} serial"), *bs, *ns));
            }
            if *bb > 0.0 && *nb > 0.0 {
                pairs.push((format!("{key} batched"), *bb, *nb));
            }
        }
    }
    if pairs.is_empty() {
        return Ok(GateReport {
            compared: 0,
            scale: 1.0,
            failures: Vec::new(),
            improvements: Vec::new(),
        });
    }
    let ratios: Vec<f64> =
        pairs.iter().map(|(_, base, fresh)| fresh / base).collect();
    let scale = crate::util::stats::median(&ratios);
    let allowance = scale * (1.0 + max_regress);
    let improve_below = scale / (1.0 + max_regress);
    let failures = pairs
        .iter()
        .zip(&ratios)
        .filter(|(_, &r)| r > allowance)
        .map(|((key, base, fresh), r)| {
            format!(
                "{key}: {fresh:.1} ns/step vs baseline {base:.1} \
                 (x{r:.2}, allowed x{allowance:.2} at machine scale \
                 {scale:.2})"
            )
        })
        .collect();
    let improvements = pairs
        .iter()
        .zip(&ratios)
        .filter(|(_, &r)| r < improve_below)
        .map(|((key, base, fresh), r)| {
            format!(
                "{key}: {fresh:.1} ns/step vs baseline {base:.1} \
                 (x{r:.2} at machine scale {scale:.2})"
            )
        })
        .collect();
    Ok(GateReport { compared: pairs.len(), scale, failures, improvements })
}

/// Speedup of `fresh` over `baseline` on one route: the ratio
/// `baseline / fresh` of the batched ns/trajectory-step at the largest
/// batch size present in both documents (plus the serial-column ratio at
/// that batch, for reporting). `None` when the route is missing from
/// either side.
///
/// This is the in-job comparison the CI quick-bench uses to assert the
/// SIMD kernels' end-to-end win: the "baseline" is a forced-scalar run
/// (`MEMODE_KERNEL=scalar`) on the *same machine moments earlier*, so no
/// machine-speed normalisation applies — unlike [`gate_against_baseline`],
/// which would normalise a uniform kernel-level speedup away.
pub fn route_speedup(
    baseline: &Json,
    fresh: &Json,
    route: &str,
) -> anyhow::Result<Option<(usize, f64, f64)>> {
    let base = bench_rows(baseline)?;
    let new = bench_rows(fresh)?;
    let mut best: Option<(usize, f64, f64)> = None;
    for (key, bs, bb) in &base {
        let Some(rest) = key.strip_prefix(route) else { continue };
        let Some(batch) = rest
            .strip_prefix(" B=")
            .and_then(|b| b.parse::<f64>().ok())
            .map(|b| b as usize)
        else {
            continue;
        };
        let Some((_, ns, nb)) = new.iter().find(|(k, _, _)| k == key)
        else {
            continue;
        };
        if *bb <= 0.0 || *nb <= 0.0 || *bs <= 0.0 || *ns <= 0.0 {
            continue;
        }
        if best.is_none_or(|(b, _, _)| batch > b) {
            best = Some((batch, bb / nb, bs / ns));
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_are_route_shaped() {
        let hp = requests("hp/analog", 3, 10);
        assert_eq!(hp.len(), 3);
        assert!(hp.iter().all(|r| r.stimulus.is_some()));
        let l96 = requests("l96/digital", 2, 10);
        assert!(l96.iter().all(|r| r.stimulus.is_none()));
        assert!(l96.iter().all(|r| r.h0.len() == 6));
    }

    #[test]
    fn json_document_shape() {
        let entries = vec![ThroughputEntry {
            route: "hp/analog",
            batch: 32,
            n_points: 12,
            serial_ns_per_step: 100.0,
            batched_ns_per_step: 40.0,
            speedup: 2.5,
        }];
        let doc = to_json("smoke", &entries);
        assert_eq!(doc.get("mode").unwrap().as_str(), Some("smoke"));
        let rows = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(
            rows[0].get("speedup").unwrap().as_f64(),
            Some(2.5)
        );
        // Round-trips through the parser.
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn bit_identity_gate_holds_on_quiet_twins() {
        assert_bit_identical("hp/analog", 4, 8);
        assert_bit_identical("l96/digital", 4, 8);
        assert_bit_identical("kuramoto/digital", 4, 8);
        assert_bit_identical("l96two/digital", 4, 8);
    }

    #[test]
    fn analytic_route_requests_are_route_shaped() {
        let kur = requests("kuramoto/digital", 2, 5);
        assert!(kur.iter().all(|r| r.h0.len() == 16));
        let two = requests("l96two/digital", 2, 5);
        assert!(two.iter().all(|r| r.h0.len() == 30));
    }

    #[test]
    fn d64_requests_are_wide() {
        let reqs = requests("l96d64/analog-shard2", 2, 5);
        assert!(reqs.iter().all(|r| r.h0.len() == 64));
    }

    #[test]
    fn ens_route_requests_carry_the_spec() {
        let reqs = requests("l96d64/analog-ens32", 2, 5);
        assert!(reqs.iter().all(|r| r.lanes() == ENS_BENCH_MEMBERS));
        assert!(reqs.iter().all(|r| r.h0.len() == 64));
        // Non-ensemble routes stay plain.
        let plain = requests("l96d64/analog", 2, 5);
        assert!(plain.iter().all(|r| r.ensemble.is_none()));
    }

    #[test]
    fn ensemble_bench_cells_over_budget_are_skipped() {
        // B=128 x 32 members would be 4096 lanes: the cell is skipped
        // (loudly) rather than silently measured or silently dropped
        // from smaller B values.
        assert!(128 * ENS_BENCH_MEMBERS > MAX_ENS_BENCH_LANES);
        assert!(32 * ENS_BENCH_MEMBERS <= MAX_ENS_BENCH_LANES);
    }

    #[test]
    fn sharded_route_bit_identical_to_monolithic_route() {
        assert_sharded_matches_monolithic(3, 4);
    }

    #[test]
    fn aged_route_bit_identical_to_monolithic_at_age_zero() {
        // The mortal deployment must cost nothing in accuracy while the
        // device is fresh: same seed, same substeps, identical rollouts.
        let mut plain = make_quiet_twin("l96d64/analog");
        let mut aged = make_quiet_twin("l96d64/analog-aged");
        for r in &requests("l96d64/analog", 2, 4) {
            assert_eq!(
                plain.run(r).unwrap().trajectory,
                aged.run(r).unwrap().trajectory,
                "aging bookkeeping changed a fresh device's rollout"
            );
        }
    }

    fn gate_doc(pairs: &[(&'static str, usize, f64, f64)]) -> Json {
        let entries: Vec<ThroughputEntry> = pairs
            .iter()
            .map(|&(route, batch, s, b)| ThroughputEntry {
                route,
                batch,
                n_points: 12,
                serial_ns_per_step: s,
                batched_ns_per_step: b,
                speedup: s / b,
            })
            .collect();
        to_json("smoke", &entries)
    }

    #[test]
    fn gate_passes_identical_documents() {
        let doc = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("l96/analog", 32, 900.0, 300.0),
        ]);
        let r = gate_against_baseline(&doc, &doc, 0.25).unwrap();
        assert!(r.passed());
        assert_eq!(r.compared, 4);
        assert!((r.scale - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gate_normalises_uniform_machine_slowdown() {
        // Everything 2x slower: a slower runner, not a regression.
        let base = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("l96/analog", 32, 900.0, 300.0),
        ]);
        let fresh = gate_doc(&[
            ("hp/analog", 32, 200.0, 80.0),
            ("l96/analog", 32, 1800.0, 600.0),
        ]);
        let r = gate_against_baseline(&base, &fresh, 0.25).unwrap();
        assert!(r.passed(), "uniform slowdown flagged: {:?}", r.failures);
        assert!((r.scale - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gate_flags_relative_regression() {
        // One route's batched path 2x slower while the rest is unchanged.
        let base = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("l96/analog", 32, 900.0, 300.0),
            ("l96/digital", 32, 50.0, 20.0),
        ]);
        let fresh = gate_doc(&[
            ("hp/analog", 32, 100.0, 80.0),
            ("l96/analog", 32, 900.0, 300.0),
            ("l96/digital", 32, 50.0, 20.0),
        ]);
        let r = gate_against_baseline(&base, &fresh, 0.25).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures.len(), 1);
        assert!(
            r.failures[0].contains("hp/analog B=32 batched"),
            "{:?}",
            r.failures
        );
    }

    #[test]
    fn gate_unseeded_baseline_passes_vacuously() {
        let base = gate_doc(&[]);
        let fresh = gate_doc(&[("hp/analog", 32, 100.0, 40.0)]);
        let r = gate_against_baseline(&base, &fresh, 0.25).unwrap();
        assert!(r.passed() && r.unseeded());
    }

    #[test]
    fn gate_reports_improvements_for_the_ratchet() {
        // One route 4x faster while the rest holds: an improvement, not a
        // machine-speed artefact — the ratchet signal.
        let base = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("l96/analog", 32, 900.0, 300.0),
            ("l96d64/analog", 32, 4000.0, 2000.0),
        ]);
        let fresh = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("l96/analog", 32, 900.0, 300.0),
            ("l96d64/analog", 32, 1000.0, 500.0),
        ]);
        let r = gate_against_baseline(&base, &fresh, 0.25).unwrap();
        assert!(r.passed(), "{:?}", r.failures);
        assert!(r.improved());
        assert_eq!(r.improvements.len(), 2);
        assert!(
            r.improvements.iter().all(|s| s.contains("l96d64/analog")),
            "{:?}",
            r.improvements
        );
        // Identical documents: nothing to ratchet.
        let same = gate_against_baseline(&base, &base, 0.25).unwrap();
        assert!(!same.improved());
    }

    #[test]
    fn route_speedup_compares_largest_common_batch() {
        let scalar = gate_doc(&[
            ("l96d64/analog", 8, 4000.0, 2400.0),
            ("l96d64/analog", 32, 4000.0, 2000.0),
            ("l96d64/analog-shard2", 32, 4000.0, 1800.0),
        ]);
        let simd = gate_doc(&[
            ("l96d64/analog", 8, 1000.0, 600.0),
            ("l96d64/analog", 32, 900.0, 400.0),
            ("l96d64/analog-shard2", 32, 1000.0, 450.0),
        ]);
        let (batch, batched, serial) =
            route_speedup(&scalar, &simd, "l96d64/analog")
                .unwrap()
                .expect("route present in both documents");
        // Largest common batch (32), not the shard2 sibling route.
        assert_eq!(batch, 32);
        assert!((batched - 5.0).abs() < 1e-12, "batched {batched}");
        assert!((serial - 4000.0 / 900.0).abs() < 1e-12);
        // Missing routes report None, never a silent 1.0x.
        assert!(route_speedup(&scalar, &simd, "hp/analog")
            .unwrap()
            .is_none());
    }

    #[test]
    fn gate_ignores_routes_missing_from_either_side() {
        let base = gate_doc(&[
            ("hp/analog", 32, 100.0, 40.0),
            ("old/route", 32, 10.0, 5.0),
        ]);
        let fresh = gate_doc(&[
            ("hp/analog", 32, 101.0, 41.0),
            ("new/route", 32, 1.0, 1.0),
        ]);
        let r = gate_against_baseline(&base, &fresh, 0.25).unwrap();
        assert_eq!(r.compared, 2);
        assert!(r.passed());
    }
}
