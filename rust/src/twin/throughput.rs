//! Serial-vs-batched twin throughput measurement — shared by the
//! `batch_throughput` bench binary (full mode, release) and the tier-1
//! smoke test (`rust/tests/bench_smoke.rs`), both of which emit the
//! machine-readable `BENCH_batch_throughput.json` at the repository root
//! so the perf trajectory is tracked from PR 2 onward.
//!
//! The metric is **ns per trajectory-step**: wall time divided by
//! `batch * n_points`, i.e. the cost of producing one output sample of one
//! trajectory. Batched wins come from amortising the weight-matrix
//! traversal, the moment-matched variance GEMM and per-request overhead
//! across the batch; the speedup column is `serial / batched` at equal work.

use std::path::PathBuf;

use crate::analog::system::AnalogNoise;
use crate::device::taox::DeviceConfig;
use crate::models::loader::MlpWeights;
use crate::twin::hp::HpTwin;
use crate::twin::lorenz96::Lorenz96Twin;
use crate::twin::{Twin, TwinRequest};
use crate::util::bench::Bencher;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;
use crate::workload::stimuli::Waveform;

/// One measured (route, batch size) cell.
#[derive(Debug, Clone)]
pub struct ThroughputEntry {
    pub route: &'static str,
    pub batch: usize,
    pub n_points: usize,
    /// Median ns per trajectory-step, B serial `run` calls.
    pub serial_ns_per_step: f64,
    /// Median ns per trajectory-step, one `run_batch` call.
    pub batched_ns_per_step: f64,
    /// serial / batched (per-step; > 1 means batching wins).
    pub speedup: f64,
}

/// The measured routes (HP and Lorenz96, analogue + digital backends).
pub const ROUTES: [&str; 4] =
    ["hp/analog", "hp/digital", "l96/analog", "l96/digital"];

fn synth_mlp(
    dims: &[(usize, usize)],
    dt: f64,
    task: &str,
    seed: u64,
) -> MlpWeights {
    let mut rng = Pcg64::seeded(seed);
    let layers = dims
        .iter()
        .map(|&(r, c)| {
            (
                Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.2, 0.2)),
                (0..c).map(|_| rng.uniform_in(-0.05, 0.05)).collect(),
            )
        })
        .collect();
    MlpWeights { layers, dt, kind: "node".into(), task: task.into() }
}

/// Trained-shape HP field: [v; h] -> 14 -> 14 -> 1 (the timing-relevant
/// structure of the real hp_node artifact).
pub fn hp_weights() -> MlpWeights {
    synth_mlp(&[(2, 14), (14, 14), (14, 1)], 1e-3, "hp", 17)
}

/// Trained-shape Lorenz96 field: 6 -> 64 -> 64 -> 6 with pseudo-random
/// weights (the timing-relevant structure of the real l96_node artifact).
pub fn l96_weights() -> MlpWeights {
    synth_mlp(&[(6, 64), (64, 64), (64, 6)], 0.02, "l96", 42)
}

/// Build the twin behind a measured route, at the paper's hardware noise
/// operating point for the analogue backends.
pub fn make_twin(route: &str) -> Box<dyn Twin> {
    let device = DeviceConfig { fault_rate: 0.0, ..Default::default() };
    match route {
        "hp/analog" => Box::new(HpTwin::analog(
            &hp_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
        )),
        "hp/digital" => Box::new(HpTwin::digital(&hp_weights())),
        "l96/analog" => Box::new(Lorenz96Twin::analog(
            &l96_weights(),
            &device,
            AnalogNoise::hardware(),
            1,
        )),
        "l96/digital" => Box::new(Lorenz96Twin::digital(&l96_weights())),
        other => panic!("unknown throughput route '{other}'"),
    }
}

/// Noise-free variant of a route's twin (for bit-identity gates).
pub fn make_quiet_twin(route: &str) -> Box<dyn Twin> {
    let quiet = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    };
    match route {
        "hp/analog" => Box::new(HpTwin::analog(
            &hp_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
        )),
        "l96/analog" => Box::new(Lorenz96Twin::analog(
            &l96_weights(),
            &quiet,
            AnalogNoise::off(),
            1,
        )),
        other => make_twin(other),
    }
}

/// Deterministic request batch for a route (driven for HP, autonomous for
/// Lorenz96; per-request stimuli / initial states differ).
pub fn requests(route: &str, b: usize, n_points: usize) -> Vec<TwinRequest> {
    let mut rng = Pcg64::seeded(7);
    let waves = [
        Waveform::sine(1.0, 4.0),
        Waveform::triangular(1.0, 4.0),
        Waveform::rectangular(1.0, 4.0),
        Waveform::modulated(1.0, 4.0, 1.0),
    ];
    (0..b)
        .map(|k| {
            if route.starts_with("hp/") {
                TwinRequest::driven(
                    vec![rng.uniform_in(0.1, 0.9)],
                    n_points,
                    waves[k % waves.len()],
                )
            } else {
                TwinRequest::autonomous(
                    (0..6).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                    n_points,
                )
            }
        })
        .collect()
}

/// Assert `run_batch` reproduces per-request `run` bit-for-bit on a
/// noise-free twin (speed never buys accuracy drift).
pub fn assert_bit_identical(route: &str, b: usize, n_points: usize) {
    let mut twin = make_quiet_twin(route);
    let reqs = requests(route, b, n_points);
    let serial: Vec<_> =
        reqs.iter().map(|r| twin.run(r).unwrap()).collect();
    let batched = twin.run_batch(&reqs);
    for (k, (got, want)) in batched.iter().zip(&serial).enumerate() {
        assert_eq!(
            got.as_ref().unwrap().trajectory,
            want.trajectory,
            "{route} request {k}: batched != serial under noise-off"
        );
    }
}

/// Measure one route at the given batch sizes.
pub fn measure_route(
    route: &'static str,
    batch_sizes: &[usize],
    n_points: usize,
    bench: &Bencher,
) -> Vec<ThroughputEntry> {
    let mut twin = make_twin(route);
    let mut entries = Vec::new();
    for &b in batch_sizes {
        let reqs = requests(route, b, n_points);
        let steps = (b * n_points) as f64;
        let serial = bench.run(&format!("{route} serial x{b}"), || {
            let mut n_ok = 0;
            for r in &reqs {
                n_ok += twin.run(r).unwrap().trajectory.len();
            }
            n_ok
        });
        let batched = bench.run(&format!("{route} run_batch B={b}"), || {
            let results = twin.run_batch(&reqs);
            assert!(results.iter().all(|r| r.is_ok()));
            results.len()
        });
        let serial_ns = serial.median.as_nanos() as f64 / steps;
        let batched_ns = batched.median.as_nanos() as f64 / steps;
        entries.push(ThroughputEntry {
            route,
            batch: b,
            n_points,
            serial_ns_per_step: serial_ns,
            batched_ns_per_step: batched_ns,
            speedup: serial_ns / batched_ns.max(1e-9),
        });
    }
    entries
}

/// Measure every route in [`ROUTES`].
pub fn measure(
    batch_sizes: &[usize],
    n_points: usize,
    bench: &Bencher,
) -> Vec<ThroughputEntry> {
    ROUTES
        .iter()
        .flat_map(|&r| measure_route(r, batch_sizes, n_points, bench))
        .collect()
}

/// Serialise entries to the tracked-benchmark JSON document.
pub fn to_json(mode: &str, entries: &[ThroughputEntry]) -> Json {
    let rows = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("route", Json::Str(e.route.to_string())),
                ("batch", Json::Num(e.batch as f64)),
                ("n_points", Json::Num(e.n_points as f64)),
                ("serial_ns_per_step", Json::Num(e.serial_ns_per_step)),
                ("batched_ns_per_step", Json::Num(e.batched_ns_per_step)),
                ("speedup", Json::Num(e.speedup)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("batch_throughput".into())),
        ("mode", Json::Str(mode.into())),
        ("unit", Json::Str("ns_per_trajectory_step".into())),
        ("entries", Json::Arr(rows)),
    ])
}

/// Where the tracked benchmark lands: `$BENCH_OUT` if set, else
/// `BENCH_batch_throughput.json` at the repository root.
pub fn default_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_OUT") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batch_throughput.json")
}

/// Write the benchmark JSON.
pub fn write_json(
    path: &std::path::Path,
    mode: &str,
    entries: &[ThroughputEntry],
) -> anyhow::Result<()> {
    crate::util::json::to_file(path, &to_json(mode, entries))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_are_route_shaped() {
        let hp = requests("hp/analog", 3, 10);
        assert_eq!(hp.len(), 3);
        assert!(hp.iter().all(|r| r.stimulus.is_some()));
        let l96 = requests("l96/digital", 2, 10);
        assert!(l96.iter().all(|r| r.stimulus.is_none()));
        assert!(l96.iter().all(|r| r.h0.len() == 6));
    }

    #[test]
    fn json_document_shape() {
        let entries = vec![ThroughputEntry {
            route: "hp/analog",
            batch: 32,
            n_points: 12,
            serial_ns_per_step: 100.0,
            batched_ns_per_step: 40.0,
            speedup: 2.5,
        }];
        let doc = to_json("smoke", &entries);
        assert_eq!(doc.get("mode").unwrap().as_str(), Some("smoke"));
        let rows = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(
            rows[0].get("speedup").unwrap().as_f64(),
            Some(2.5)
        );
        // Round-trips through the parser.
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn bit_identity_gate_holds_on_quiet_twins() {
        assert_bit_identical("hp/analog", 4, 8);
        assert_bit_identical("l96/digital", 4, 8);
    }
}
