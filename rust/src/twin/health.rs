//! Device-lifetime health monitoring: the detect → recalibrate → degrade
//! loop over an aging analogue deployment.
//!
//! [`MonitoredTwin`] wraps a mortal analogue twin
//! ([`Lorenz96Twin::analog_aging`] or the HP equivalent — monitoring
//! composes at the generic-core layer, so any [`DynamicsTwin`] family
//! fits) together with its golden digital reference. Serving advances
//! the hardware's *virtual* clock (never
//! wall-clock — see the device-lifetime invariants in `lib.rs`); every
//! `probe_every` rollouts a cheap probe rollout is compared against the
//! digital reference with the paper's MRE metric (Eq. 5), and a probe
//! crossing [`LifetimeConfig::mre_threshold`] triggers a recalibration
//! episode: reprogram every array toward its logical target, charge the
//! write-verify pulses as energy ([`crate::energy::recalibration_energy`]),
//! wait out an exponentially growing virtual downtime, re-probe, retry up
//! to [`LifetimeConfig::max_retries`] times.
//!
//! A stuck-heavy array cannot be written back to health: after
//! [`LifetimeConfig::max_recal_failures`] consecutive failed episodes the
//! route enters *degraded* service — requests are answered by the digital
//! reference with [`TwinResponse::degraded`] stamped `true`, so clients
//! always know when the analogue hardware is out of the loop.
//!
//! Fault-injection campaigns ride on ensemble requests
//! ([`FaultCampaign`]): each member gets its own sampled deployment
//! (yield map seeded from the campaign's `yield_seed`), extra stuck cells
//! and an aging horizon, so the pooled statistics describe a *population
//! of devices*. Campaigns are bit-replayable from the (request seed,
//! yield seed) pair — `rust/tests/lifetime.rs` asserts it.

use std::sync::Arc;

use anyhow::Result;

use crate::analog::system::AnalogNoise;
use crate::coordinator::telemetry::Telemetry;
use crate::device::taox::DeviceConfig;
use crate::metrics::mre::mre_eps;
use crate::models::loader::MlpWeights;
use crate::twin::core::DynamicsTwin;
use crate::twin::hp::HpTwin;
use crate::twin::lorenz96::Lorenz96Twin;
use crate::twin::{
    assemble_ensemble_stats, ensemble_member_seed, EnsembleSlot,
    EnsembleSpec, EnsembleStats, FaultCampaign, Twin, TwinRequest,
    TwinResponse,
};
use crate::util::rng::{derive_stream_seed, SeedSequencer};
use crate::util::stats::EnsembleAccumulator;
use crate::util::tensor::{Trajectory, TrajectoryPool};
use crate::workload::stimuli::Waveform;

/// Stream tag of the monitor's own auto-seed family (distinct from the
/// deploy and aging streams derived off the same deployment seed).
const HEALTH_SEED_TAG: u64 = 0x4ea1_7400_0000_0002;

/// Guard band of the probe MRE: relative error is meaningless where the
/// golden trajectory grazes zero, so samples below this magnitude are
/// excluded (the paper's Eq. 5 with a practical guard).
const PROBE_MRE_EPS: f64 = 1e-2;

/// Lifetime-management policy of a [`MonitoredTwin`].
#[derive(Debug, Clone)]
pub struct LifetimeConfig {
    /// Virtual device time charged per served rollout (s).
    pub age_per_rollout_s: f64,
    /// Probe the hardware every this many served rollouts.
    pub probe_every: u64,
    /// Probe rollout length (samples) — cheap by construction.
    pub probe_points: usize,
    /// Fixed noise seed of the probe rollouts (probes are replayable).
    pub probe_seed: u64,
    /// Probe MRE above this triggers a recalibration episode.
    pub mre_threshold: f64,
    /// Write-verify retries per recalibration episode.
    pub max_retries: u32,
    /// Virtual downtime of the first retry (s); doubles per retry, and
    /// the device keeps drifting while it is being serviced.
    pub backoff_s: f64,
    /// Consecutive failed episodes before the route degrades.
    pub max_recal_failures: u32,
}

impl Default for LifetimeConfig {
    fn default() -> Self {
        Self {
            age_per_rollout_s: 86_400.0,
            probe_every: 8,
            probe_points: 16,
            probe_seed: 0x9043_e5ee_d000_0001,
            mre_threshold: 0.05,
            max_retries: 3,
            backoff_s: 60.0,
            max_recal_failures: 3,
        }
    }
}

/// Point-in-time lifetime status of a monitored route (what the
/// coordinator's telemetry snapshot carries per route).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LifetimeSnapshot {
    /// Virtual device age (s).
    pub age_s: f64,
    /// Healthy-cell fraction across the deployed arrays.
    pub array_health: f64,
    /// Probes run so far.
    pub probes: u64,
    /// Most recent probe MRE vs the digital reference.
    pub last_probe_mre: f64,
    /// Completed recalibrations (array reprogramming passes).
    pub recalibrations: u64,
    /// Lifetime write-verify pulses spent recalibrating.
    pub recal_pulses: u64,
    /// Energy of those pulses (J).
    pub recal_energy_j: f64,
    /// Recalibration episodes that exhausted their retries.
    pub recal_failures: u64,
    /// Whether the route serves degraded (digital fallback) responses.
    pub degraded: bool,
    /// Fault-campaign members simulated through this route.
    pub campaign_members: u64,
    /// Of those, members whose rollout error crossed the probe threshold.
    pub campaign_degraded: u64,
}

/// Probe error between a rollout and its golden reference: MRE over the
/// flat sample streams, zero-guarded (see [`PROBE_MRE_EPS`]).
pub fn probe_mre(pred: &Trajectory, truth: &Trajectory) -> f64 {
    mre_eps(pred.data(), truth.data(), PROBE_MRE_EPS)
}

/// Which twin family a monitor wraps — the recipe fault campaigns use to
/// sample fresh per-member deployments of the same logical model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MonitoredKind {
    Lorenz96,
    Hp,
}

/// An aging analogue twin under health management, with its digital
/// reference as both probe oracle and degraded-service fallback.
pub struct MonitoredTwin {
    analog: DynamicsTwin,
    digital: DynamicsTwin,
    kind: MonitoredKind,
    /// Probe stimulus for driven families (autonomous probes pass none).
    probe_wave: Option<Waveform>,
    cfg: LifetimeConfig,
    /// Deployment recipe retained for fault-campaign members (each member
    /// is a fresh sampled deployment of the same logical model).
    weights: MlpWeights,
    device: DeviceConfig,
    noise: AnalogNoise,
    substeps: usize,
    seeds: SeedSequencer,
    route: String,
    telemetry: Option<Arc<Telemetry>>,
    served: u64,
    probes: u64,
    last_probe_mre: f64,
    consecutive_failures: u32,
    recal_failures: u64,
    recal_pulses: u64,
    degraded: bool,
    campaign_members: u64,
    campaign_degraded: u64,
    pool: TrajectoryPool,
    acc: EnsembleAccumulator,
}

impl MonitoredTwin {
    /// Monitored Lorenz96 twin: mortal analogue deployment + digital
    /// golden reference built from the same trained weights.
    pub fn lorenz96(
        weights: &MlpWeights,
        device: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
        cfg: LifetimeConfig,
    ) -> Self {
        let analog =
            Lorenz96Twin::analog_aging(weights, device, noise, seed, substeps)
                .into_core();
        let digital = Lorenz96Twin::digital(weights).into_core();
        Self::assemble(
            MonitoredKind::Lorenz96,
            analog,
            digital,
            None,
            "lorenz96/analog-aged",
            weights,
            device,
            noise,
            seed,
            substeps,
            cfg,
        )
    }

    /// Monitored HP twin: the driven scalar family under the same
    /// detect → recalibrate → degrade loop. Probes carry the standard
    /// probe stimulus (driven twins reject stimulus-free requests).
    pub fn hp(
        weights: &MlpWeights,
        device: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
        cfg: LifetimeConfig,
    ) -> Self {
        let analog =
            HpTwin::analog_aging(weights, device, noise, seed, substeps)
                .into_core();
        let digital = HpTwin::digital(weights).into_core();
        Self::assemble(
            MonitoredKind::Hp,
            analog,
            digital,
            Some(Waveform::sine(1.0, 50.0)),
            "hp/analog-aged",
            weights,
            device,
            noise,
            seed,
            substeps,
            cfg,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        kind: MonitoredKind,
        analog: DynamicsTwin,
        digital: DynamicsTwin,
        probe_wave: Option<Waveform>,
        route: &str,
        weights: &MlpWeights,
        device: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
        cfg: LifetimeConfig,
    ) -> Self {
        Self {
            analog,
            digital,
            kind,
            probe_wave,
            cfg,
            weights: weights.clone(),
            device: device.clone(),
            noise,
            substeps: substeps.max(1),
            seeds: SeedSequencer::new(derive_stream_seed(
                seed,
                HEALTH_SEED_TAG,
            )),
            route: route.into(),
            telemetry: None,
            served: 0,
            probes: 0,
            last_probe_mre: 0.0,
            consecutive_failures: 0,
            recal_failures: 0,
            recal_pulses: 0,
            degraded: false,
            campaign_members: 0,
            campaign_degraded: 0,
            pool: TrajectoryPool::new(),
            acc: EnsembleAccumulator::default(),
        }
    }

    /// Publish lifetime snapshots into the coordinator's telemetry under
    /// `route`.
    pub fn with_telemetry(
        mut self,
        route: &str,
        t: Arc<Telemetry>,
    ) -> Self {
        self.route = route.to_owned();
        self.telemetry = Some(t);
        self.publish();
        self
    }

    /// Whether the route has entered degraded (digital-fallback) service.
    pub fn is_degraded(&self) -> bool {
        self.degraded
    }

    /// Current lifetime status.
    pub fn lifetime(&self) -> LifetimeSnapshot {
        LifetimeSnapshot {
            age_s: self.analog.age_s(),
            array_health: self.analog.array_health(),
            probes: self.probes,
            last_probe_mre: self.last_probe_mre,
            recalibrations: self.analog.recalibrations(),
            recal_pulses: self.recal_pulses,
            recal_energy_j: crate::energy::recalibration_energy(
                self.recal_pulses,
            ),
            recal_failures: self.recal_failures,
            degraded: self.degraded,
            campaign_members: self.campaign_members,
            campaign_degraded: self.campaign_degraded,
        }
    }

    /// Advance the hardware's virtual clock directly (accelerated-aging
    /// experiments; serving already ages per rollout).
    pub fn advance_age(&mut self, dt_s: f64) {
        self.analog.advance_age(dt_s);
        self.publish();
    }

    /// Mark a random fraction of cells stuck on the monitored deployment
    /// (deterministic in its aging stream) — the forced-failure lever of
    /// lifetime scenarios and tests.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        self.analog.inject_stuck_faults(fraction);
        self.publish();
    }

    /// Rollout error of the monitored hardware against its digital
    /// reference on the standard probe request.
    fn probe_error(&mut self) -> Result<f64> {
        let n = self.cfg.probe_points.max(2);
        let req = match self.probe_wave {
            Some(wave) => TwinRequest::driven(Vec::new(), n, wave),
            None => TwinRequest::autonomous(Vec::new(), n),
        }
        .with_seed(self.cfg.probe_seed);
        let a = self.analog.run(&req)?;
        let d = self.digital.run(&req)?;
        Ok(probe_mre(&a.trajectory, &d.trajectory))
    }

    /// Run one health probe immediately; on a threshold crossing, run a
    /// full recalibration episode (bounded retries, exponential virtual
    /// backoff). Returns the final probe error.
    pub fn probe_now(&mut self) -> Result<f64> {
        let mut err = self.probe_error()?;
        self.probes += 1;
        if err > self.cfg.mre_threshold && !self.degraded {
            let mut recovered = false;
            for attempt in 0..self.cfg.max_retries {
                let pulses = self.analog.recalibrate();
                self.recal_pulses =
                    self.recal_pulses.saturating_add(pulses);
                // Write-verify downtime doubles per retry, in virtual
                // time: the device drifts even while being serviced.
                self.analog.advance_age(
                    self.cfg.backoff_s
                        * f64::from(1u32 << attempt.min(30)),
                );
                err = self.probe_error()?;
                if err <= self.cfg.mre_threshold {
                    recovered = true;
                    break;
                }
            }
            if recovered {
                self.consecutive_failures = 0;
            } else {
                self.consecutive_failures += 1;
                self.recal_failures += 1;
                if self.consecutive_failures
                    >= self.cfg.max_recal_failures.max(1)
                {
                    self.degraded = true;
                }
            }
        } else if err <= self.cfg.mre_threshold {
            self.consecutive_failures = 0;
        }
        self.last_probe_mre = err;
        self.publish();
        Ok(err)
    }

    fn publish(&self) {
        if let Some(t) = &self.telemetry {
            t.record_lifetime(&self.route, self.lifetime());
        }
    }

    /// Execute a fault-injection campaign: each member is a *fresh
    /// sampled deployment* (yield map from `derive_stream_seed(yield_seed,
    /// k)`), salted with extra stuck cells, aged to the campaign horizon,
    /// then rolled out under noise seed `ensemble_member_seed(seed, k)`.
    /// Pooled stats come from the shared ensemble assembly, plus a pooled
    /// degradation count against the digital reference.
    fn run_fault_campaign(
        &mut self,
        req: &TwinRequest,
        spec: &EnsembleSpec,
        campaign: FaultCampaign,
    ) -> Result<TwinResponse> {
        spec.validate()?;
        let seed = self.seeds.resolve(req.seed);
        let n = spec.members;
        let dim = self.analog.state_dim();
        let mut plain = req.clone();
        plain.ensemble = None;
        plain.seed = Some(seed);
        let golden = self.digital.run(&plain)?.trajectory;
        let mut members: Vec<Trajectory> = Vec::with_capacity(n);
        let mut degraded_members = 0u64;
        for k in 0..n {
            let dep_seed =
                derive_stream_seed(campaign.yield_seed, k as u64);
            let mut device = match self.kind {
                MonitoredKind::Lorenz96 => Lorenz96Twin::analog_aging(
                    &self.weights,
                    &self.device,
                    self.noise,
                    dep_seed,
                    self.substeps,
                )
                .into_core(),
                MonitoredKind::Hp => HpTwin::analog_aging(
                    &self.weights,
                    &self.device,
                    self.noise,
                    dep_seed,
                    self.substeps,
                )
                .into_core(),
            };
            if campaign.fault_fraction > 0.0 {
                device.inject_stuck_faults(campaign.fault_fraction);
            }
            if campaign.age_s > 0.0 {
                device.advance_age(campaign.age_s);
            }
            let mut mreq = plain.clone();
            mreq.seed = Some(ensemble_member_seed(seed, k as u64));
            let resp = device.run(&mreq)?;
            if probe_mre(&resp.trajectory, &golden)
                > self.cfg.mre_threshold
            {
                degraded_members += 1;
            }
            members.push(resp.trajectory);
        }
        let n_points = members.first().map_or(0, Trajectory::len);
        let mut flat = Trajectory::new(n * dim);
        flat.reserve_rows(n_points);
        for r in 0..n_points {
            flat.push_row_from_iter(
                members.iter().flat_map(|m| m.row(r).iter().copied()),
            );
        }
        let (trajectory, stats) = assemble_ensemble_stats(
            spec,
            &flat,
            EnsembleSlot { batch: n, dim, base: 0 },
            &mut self.acc,
            &mut self.pool,
            EnsembleStats::default(),
        );
        self.campaign_members =
            self.campaign_members.saturating_add(n as u64);
        self.campaign_degraded =
            self.campaign_degraded.saturating_add(degraded_members);
        self.publish();
        Ok(TwinResponse {
            trajectory,
            backend: "analog-aged-campaign",
            seed,
            ensemble: Some(stats),
            degraded: false,
        })
    }
}

impl Twin for MonitoredTwin {
    fn name(&self) -> &str {
        &self.route
    }

    fn state_dim(&self) -> usize {
        self.analog.state_dim()
    }

    fn dt(&self) -> f64 {
        self.analog.dt()
    }

    fn default_h0(&self) -> Vec<f64> {
        self.analog.default_h0()
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        if let Some(c) =
            req.ensemble.as_ref().and_then(|s| s.fault_campaign)
        {
            let spec = req.ensemble.clone().expect("campaign implies spec");
            return self.run_fault_campaign(req, &spec, c);
        }
        if self.degraded {
            // Graceful degradation: keep serving, from the digital
            // reference, and say so.
            let mut resp = self.digital.run(req)?;
            resp.degraded = true;
            self.publish();
            return Ok(resp);
        }
        let resp = self.analog.run(req)?;
        self.served += 1;
        self.analog.advance_age(self.cfg.age_per_rollout_s);
        if self.served % self.cfg.probe_every.max(1) == 0 {
            self.probe_now()?;
        } else {
            self.publish();
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::loader::decay_mlp_weights;

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        }
    }

    /// High substeps keep the circuit-integrator error floor far below
    /// every probe threshold used here (the probe compares the analogue
    /// circuit integration against digital RK4).
    fn monitored(cfg: LifetimeConfig) -> MonitoredTwin {
        MonitoredTwin::lorenz96(
            &decay_mlp_weights(3),
            &quiet_cfg(),
            AnalogNoise::off(),
            11,
            100,
            cfg,
        )
    }

    #[test]
    fn healthy_twin_serves_analog_and_probes_clean() {
        let mut t = monitored(LifetimeConfig {
            age_per_rollout_s: 1.0,
            probe_every: 2,
            ..Default::default()
        });
        for _ in 0..4 {
            let r = t
                .run(&TwinRequest::autonomous(vec![0.5, -0.5, 0.2], 6))
                .unwrap();
            assert_eq!(r.backend, "analog");
            assert!(!r.degraded);
        }
        let s = t.lifetime();
        assert_eq!(s.probes, 2);
        assert!(s.last_probe_mre < 0.05, "mre {}", s.last_probe_mre);
        assert_eq!(s.recalibrations, 0);
        assert!(!s.degraded);
        assert!(s.age_s > 0.0);
    }

    #[test]
    fn drifted_twin_recalibrates_and_recovers() {
        let mut t = monitored(LifetimeConfig {
            mre_threshold: 0.005,
            probe_points: 50,
            ..Default::default()
        });
        t.advance_age(1e10);
        let before = t.probe_error().unwrap();
        assert!(before > 0.005, "drift inert: {before}");
        let after = t.probe_now().unwrap();
        let s = t.lifetime();
        assert!(s.recalibrations >= 1);
        assert!(s.recal_pulses > 0);
        assert!(s.recal_energy_j > 0.0);
        assert!(after <= 0.005, "not restored: {after}");
        assert!(!s.degraded);
    }

    #[test]
    fn stuck_heavy_twin_exhausts_retries_and_degrades() {
        let mut t = monitored(LifetimeConfig {
            mre_threshold: 1e-6,
            max_retries: 2,
            max_recal_failures: 1,
            backoff_s: 1.0,
            ..Default::default()
        });
        t.inject_stuck_faults(0.6);
        assert!(t.array_health_below_one());
        let _ = t.probe_now().unwrap();
        assert!(t.is_degraded(), "over-faulted array failed to degrade");
        let s = t.lifetime();
        assert_eq!(s.recal_failures, 1);
        assert!(s.recalibrations >= 1, "degradation without trying");
        // Degraded service: digital fallback, flagged.
        let r = t
            .run(&TwinRequest::autonomous(vec![0.1, 0.2, 0.3], 5))
            .unwrap();
        assert!(r.degraded);
        assert_eq!(r.backend, "digital-rk4");
        assert_eq!(r.trajectory.len(), 5);
    }

    impl MonitoredTwin {
        fn array_health_below_one(&self) -> bool {
            self.analog.array_health() < 1.0
        }
    }

    #[test]
    fn hp_monitored_twin_serves_and_probes_driven() {
        let mut t = MonitoredTwin::hp(
            &crate::twin::throughput::hp_weights(),
            &quiet_cfg(),
            AnalogNoise::off(),
            13,
            100,
            LifetimeConfig {
                age_per_rollout_s: 1.0,
                probe_every: 2,
                ..Default::default()
            },
        );
        assert_eq!(t.name(), "hp/analog-aged");
        assert_eq!(t.state_dim(), 1);
        let wave = Waveform::sine(1.0, 50.0);
        for _ in 0..4 {
            let r = t
                .run(&TwinRequest::driven(vec![], 6, wave))
                .unwrap();
            assert_eq!(r.backend, "analog");
            assert!(!r.degraded);
        }
        let s = t.lifetime();
        assert_eq!(s.probes, 2);
        assert!(s.last_probe_mre < 0.05, "mre {}", s.last_probe_mre);
        assert!(!s.degraded);
    }

    #[test]
    fn fault_campaign_is_replayable_and_pools_degradation() {
        let spec = EnsembleSpec::new(3).with_fault_campaign(
            FaultCampaign::new(77).aged(1e7).with_fault_fraction(0.05),
        );
        let req = TwinRequest::autonomous(vec![0.4, -0.2, 0.6], 5)
            .with_seed(2024)
            .with_ensemble(spec);
        let mut a = monitored(LifetimeConfig::default());
        let mut b = monitored(LifetimeConfig::default());
        let ra = a.run(&req).unwrap();
        let rb = b.run(&req).unwrap();
        assert_eq!(ra.trajectory, rb.trajectory, "campaign not replayable");
        let (ea, eb) =
            (ra.ensemble.as_ref().unwrap(), rb.ensemble.as_ref().unwrap());
        assert_eq!(ea.mean, eb.mean);
        assert_eq!(ea.std, eb.std);
        assert_eq!(ea.members, 3);
        assert_eq!(a.lifetime().campaign_members, 3);
        // A different yield seed samples different hardware.
        let other = TwinRequest::autonomous(vec![0.4, -0.2, 0.6], 5)
            .with_seed(2024)
            .with_ensemble(EnsembleSpec::new(3).with_fault_campaign(
                FaultCampaign::new(78).aged(1e7).with_fault_fraction(0.05),
            ));
        let rc = a.run(&other).unwrap();
        assert_ne!(
            rc.trajectory, ra.trajectory,
            "yield seed had no effect on the device population"
        );
    }
}
