//! Twin registry: names -> twin factories.
//!
//! The coordinator's workers each own private twin instances (twins are
//! stateful: integrator charge, recurrent hidden state, RNG streams), so
//! the registry stores *factories* rather than instances. Factories are
//! `Send + Sync` and cheap to call; the expensive parts (weight loading,
//! array deployment) happen once inside the factory's captured state.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::twin::Twin;

/// A thread-safe twin factory.
pub type TwinFactory = Arc<dyn Fn() -> Box<dyn Twin> + Send + Sync>;

/// Static metadata describing a registered route: what the serve-time
/// route table prints, what `unknown_route` errors enumerate, and what
/// the router's pre-admission `y0` dimension check validates against.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteInfo {
    /// State dimension the route's twin integrates.
    pub dim: usize,
    /// Output sample interval (s).
    pub dt: f64,
    /// Backend family label (e.g. `"analog"`, `"digital-rk4"`).
    pub backend: &'static str,
    /// Whether the route runs on mortal (health-monitored) hardware.
    pub aged: bool,
    /// Whether the route serves synthetic weights (no trained artifact).
    pub synthetic: bool,
}

/// Registry of available twins.
#[derive(Clone, Default)]
pub struct TwinRegistry {
    factories: BTreeMap<String, TwinFactory>,
    infos: BTreeMap<String, RouteInfo>,
}

impl TwinRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a factory under a route key (e.g. "hp/analog").
    pub fn register(
        &mut self,
        key: &str,
        factory: impl Fn() -> Box<dyn Twin> + Send + Sync + 'static,
    ) {
        self.factories.insert(key.to_string(), Arc::new(factory));
    }

    /// Register a factory together with its route metadata.
    pub fn register_info(
        &mut self,
        key: &str,
        info: RouteInfo,
        factory: impl Fn() -> Box<dyn Twin> + Send + Sync + 'static,
    ) {
        self.register(key, factory);
        self.infos.insert(key.to_string(), info);
    }

    /// Metadata of a route, when it was registered with any.
    pub fn info(&self, key: &str) -> Option<&RouteInfo> {
        self.infos.get(key)
    }

    /// Route keys annotated with their state dimension where known —
    /// the payload of `unknown_route` errors.
    pub fn describe_routes(&self) -> Vec<String> {
        self.keys()
            .into_iter()
            .map(|k| match self.infos.get(&k) {
                Some(i) => format!("{k} (dim {})", i.dim),
                None => k,
            })
            .collect()
    }

    /// Instantiate a twin.
    pub fn create(&self, key: &str) -> Result<Box<dyn Twin>> {
        let f = self.factories.get(key).ok_or_else(|| {
            anyhow!(
                "unknown twin '{key}' (available: {})",
                self.keys().join(", ")
            )
        })?;
        Ok(f())
    }

    /// Registered route keys, sorted.
    pub fn keys(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.factories.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.factories.len()
    }

    pub fn is_empty(&self) -> bool {
        self.factories.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{TwinRequest, TwinResponse};

    struct DummyTwin;

    impl Twin for DummyTwin {
        fn name(&self) -> &str {
            "dummy"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            0.1
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
            Ok(TwinResponse {
                trajectory: crate::util::tensor::Trajectory::repeat_row(
                    &[0.0],
                    req.n_points,
                ),
                backend: "dummy",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    #[test]
    fn register_and_create() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        assert!(reg.contains("dummy"));
        assert_eq!(reg.len(), 1);
        let mut twin = reg.create("dummy").unwrap();
        let resp = twin.run(&TwinRequest::autonomous(vec![], 3)).unwrap();
        assert_eq!(resp.trajectory.len(), 3);
    }

    #[test]
    fn unknown_key_lists_available() {
        let mut reg = TwinRegistry::new();
        reg.register("hp/analog", || Box::new(DummyTwin));
        let err = match reg.create("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("unknown key accepted"),
        };
        assert!(err.contains("hp/analog"));
    }

    #[test]
    fn factories_produce_independent_instances() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        let a = reg.create("dummy").unwrap();
        let b = reg.create("dummy").unwrap();
        // Just type-level: both exist simultaneously (no shared &mut).
        assert_eq!(a.name(), b.name());
    }

    #[test]
    fn registry_clone_shares_factories() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        let reg2 = reg.clone();
        assert!(reg2.contains("dummy"));
    }

    #[test]
    fn route_info_is_stored_and_described() {
        let mut reg = TwinRegistry::new();
        reg.register_info(
            "hp/analog",
            RouteInfo {
                dim: 1,
                dt: 1e-3,
                backend: "analog",
                aged: false,
                synthetic: false,
            },
            || Box::new(DummyTwin),
        );
        reg.register("bare/route", || Box::new(DummyTwin));
        let info = reg.info("hp/analog").expect("info registered");
        assert_eq!(info.dim, 1);
        assert_eq!(info.backend, "analog");
        assert!(reg.info("bare/route").is_none());
        let described = reg.describe_routes();
        assert_eq!(
            described,
            vec!["bare/route".to_string(), "hp/analog (dim 1)".to_string()]
        );
    }
}
