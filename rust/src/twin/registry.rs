//! Twin registry: names -> twin factories.
//!
//! The coordinator's workers each own private twin instances (twins are
//! stateful: integrator charge, recurrent hidden state, RNG streams), so
//! the registry stores *factories* rather than instances. Factories are
//! `Send + Sync` and cheap to call; the expensive parts (weight loading,
//! array deployment) happen once inside the factory's captured state.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::twin::Twin;

/// A thread-safe twin factory.
pub type TwinFactory = Arc<dyn Fn() -> Box<dyn Twin> + Send + Sync>;

/// Registry of available twins.
#[derive(Clone, Default)]
pub struct TwinRegistry {
    factories: BTreeMap<String, TwinFactory>,
}

impl TwinRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a factory under a route key (e.g. "hp/analog").
    pub fn register(
        &mut self,
        key: &str,
        factory: impl Fn() -> Box<dyn Twin> + Send + Sync + 'static,
    ) {
        self.factories.insert(key.to_string(), Arc::new(factory));
    }

    /// Instantiate a twin.
    pub fn create(&self, key: &str) -> Result<Box<dyn Twin>> {
        let f = self.factories.get(key).ok_or_else(|| {
            anyhow!(
                "unknown twin '{key}' (available: {})",
                self.keys().join(", ")
            )
        })?;
        Ok(f())
    }

    /// Registered route keys, sorted.
    pub fn keys(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.factories.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.factories.len()
    }

    pub fn is_empty(&self) -> bool {
        self.factories.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{TwinRequest, TwinResponse};

    struct DummyTwin;

    impl Twin for DummyTwin {
        fn name(&self) -> &str {
            "dummy"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            0.1
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
            Ok(TwinResponse {
                trajectory: crate::util::tensor::Trajectory::repeat_row(
                    &[0.0],
                    req.n_points,
                ),
                backend: "dummy",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    #[test]
    fn register_and_create() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        assert!(reg.contains("dummy"));
        assert_eq!(reg.len(), 1);
        let mut twin = reg.create("dummy").unwrap();
        let resp = twin.run(&TwinRequest::autonomous(vec![], 3)).unwrap();
        assert_eq!(resp.trajectory.len(), 3);
    }

    #[test]
    fn unknown_key_lists_available() {
        let mut reg = TwinRegistry::new();
        reg.register("hp/analog", || Box::new(DummyTwin));
        let err = match reg.create("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("unknown key accepted"),
        };
        assert!(err.contains("hp/analog"));
    }

    #[test]
    fn factories_produce_independent_instances() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        let a = reg.create("dummy").unwrap();
        let b = reg.create("dummy").unwrap();
        // Just type-level: both exist simultaneously (no shared &mut).
        assert_eq!(a.name(), b.name());
    }

    #[test]
    fn registry_clone_shares_factories() {
        let mut reg = TwinRegistry::new();
        reg.register("dummy", || Box::new(DummyTwin));
        let reg2 = reg.clone();
        assert!(reg2.contains("dummy"));
    }
}
