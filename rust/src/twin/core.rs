//! The generic twin core: one request-execution engine shared by every
//! registered twin.
//!
//! Historically the HP and Lorenz96 twins each hand-rolled the same
//! machinery — group planning, stimulus/initial-state staging, seed
//! resolution, per-lane noise derivation, ensemble expansion, pooled
//! response assembly and the sharded/co-scheduled dispatch forms. That
//! machinery now lives here once, in [`DynamicsTwin`]: a twin is a
//! [`TwinSpec`] (name, dimension, sampling step, default initial state,
//! stimulus kind) plus a [`CoreBackend`] (where the vector field actually
//! executes). The HP and Lorenz96 twins are thin configuration wrappers
//! over this type, and new worlds (Kuramoto, two-level Lorenz96) are a
//! [`DynField`] implementation plus a registry stanza — see
//! `docs/ARCHITECTURE.md` for the ~100-line recipe.
//!
//! Every cross-twin invariant is therefore enforced against *this* path:
//! batched rollouts bit-identical to serial ones (noise on or off),
//! allocation-free warm batches on the Analog/Digital backends, seeded
//! noise-lane determinism across batch composition and shard layout, and
//! ensemble member replay via
//! [`ensemble_member_seed`](crate::twin::ensemble_member_seed).

use anyhow::{anyhow, Result};

use crate::analog::system::{AnalogMlp, AnalogNeuralOde};
use crate::models::mlp::{
    BatchDrivenMlpField, BatchMlpField, DrivenMlpField, Mlp, MlpField,
};
use crate::models::resnet::RecurrentResNet;
use crate::models::rnn::Recurrent;
use crate::ode::batch::{unbatch_into, BatchVectorField};
use crate::ode::func::VectorField;
use crate::ode::rk4::{self, Rk4};
use crate::twin::shard::{ShardGroup, ShardSnapshot, ShardedAnalogOde};
use crate::twin::{
    assemble_ensemble_stats, ensemble_member_seed, EnsembleStats, GroupPlan,
    RolloutFn, Twin, TwinRequest, TwinResponse, MAX_SUB_BATCH_LANES,
};
use crate::util::rng::{NoiseLane, SeedSequencer};
use crate::util::stats::EnsembleAccumulator;
use crate::util::tensor::{Trajectory, TrajectoryPool};
use crate::workload::stimuli::Waveform;

/// How a twin consumes the request's [`Waveform`] stimulus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StimulusKind {
    /// The system evolves on its own; request stimuli are ignored.
    Autonomous,
    /// A scalar drive `u(t)` is written into input slot 0 each substep;
    /// requests without a stimulus are rejected per-request.
    DrivenScalar,
}

/// Static configuration of a [`DynamicsTwin`]: everything about a twin
/// that is not "where does the vector field execute".
#[derive(Debug, Clone)]
pub struct TwinSpec {
    /// Twin name (the route-key prefix, e.g. `"lorenz96"`).
    pub name: &'static str,
    /// Diagnostic label surfaced by solver dim asserts (route key).
    pub field_label: &'static str,
    /// State dimension.
    pub dim: usize,
    /// Sampling interval of one output step (s).
    pub dt: f64,
    /// Default initial condition (used when a request's `h0` is empty).
    pub default_h0: Vec<f64>,
    /// Stimulus contract of the twin.
    pub stimulus: StimulusKind,
    /// RK4 substeps per output sample on the digital backend.
    pub digital_substeps: usize,
}

/// An object-safe autonomous vector field dx/dt = f(t, x): the ~100-line
/// surface a new twin implements. `eval_into` takes `&self` so one boxed
/// field serves both the serial and the lane-looped batched adapters
/// without scratch aliasing.
pub trait DynField: Send {
    /// State dimension.
    fn dim(&self) -> usize;

    /// Evaluate f(t, x) into `out` (len == dim()).
    fn eval_into(&self, t: f64, x: &[f64], out: &mut [f64]);
}

/// Serial [`VectorField`] view of a [`DynField`].
struct SerialDynField<'a> {
    field: &'a dyn DynField,
    label: &'static str,
}

impl VectorField for SerialDynField<'_> {
    fn dim(&self) -> usize {
        self.field.dim()
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]) {
        self.field.eval_into(t, x, out);
    }
}

/// Batched [`BatchVectorField`] view of a [`DynField`]: lanes advance in
/// lockstep by looping the scalar field over per-lane subslices, so the
/// batched solve stays allocation-free and bit-identical to serial.
struct BatchDynField<'a> {
    field: &'a dyn DynField,
    batch: usize,
    label: &'static str,
}

impl BatchVectorField for BatchDynField<'_> {
    fn dim(&self) -> usize {
        self.field.dim()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_batch_into(&mut self, t: f64, xs: &[f64], out: &mut [f64]) {
        let d = self.field.dim();
        for b in 0..self.batch {
            let lo = b * d;
            self.field.eval_into(t, &xs[lo..lo + d], &mut out[lo..lo + d]);
        }
    }
}

/// The model behind the digital (Rust RK4) backend.
pub enum DigitalModel {
    /// A trained neural-ODE field (per-layer GEMM batched path).
    Mlp(Mlp),
    /// A closed-form vector field ([`DynField`]) — how the zoo's
    /// analytical worlds (Kuramoto, two-level Lorenz96) plug in.
    Field(Box<dyn DynField>),
}

/// Execution backend of a [`DynamicsTwin`] — the union of every backend
/// the HP and Lorenz96 twins historically supported.
pub enum CoreBackend {
    /// Simulated memristive solver at a noise operating point.
    Analog(Box<AnalogNeuralOde>),
    /// Tile-sharded fan-out: one rollout spread across parallel shard
    /// workers (states wider than one physical array).
    AnalogSharded(Box<ShardedAnalogOde>),
    /// Rust-native RK4 over a trained MLP or a closed-form field.
    Digital(DigitalModel),
    /// Recurrent baseline (RNN / GRU / LSTM).
    Recurrent(Box<dyn Recurrent + Send>),
    /// Recurrent-ResNet discrete baseline (driven twins only).
    Resnet(RecurrentResNet),
    /// AOT HLO rollout via PJRT.
    Pjrt(RolloutFn),
}

impl CoreBackend {
    /// Telemetry label stamped into responses.
    pub fn label(&self) -> &'static str {
        match self {
            CoreBackend::Analog(_) => "analog",
            CoreBackend::AnalogSharded(_) => "analog-sharded",
            CoreBackend::Digital(_) => "digital-rk4",
            CoreBackend::Recurrent(_) => "recurrent",
            CoreBackend::Resnet(_) => "resnet",
            CoreBackend::Pjrt(_) => "pjrt",
        }
    }

    /// Backend *family* name for route metadata (see
    /// [`crate::twin::registry::RouteInfo`]).
    pub fn family(&self) -> &'static str {
        self.label()
    }
}

/// Reusable batch scratch: everything `run_batch_into` needs between the
/// request slice and the response vector lives here so a warm twin never
/// allocates. Taken out of `self` with `mem::take` for the duration of a
/// batch (its `Default` is allocation-free) to sidestep borrow conflicts
/// with the backend.
#[derive(Default)]
struct CoreScratch {
    plan: GroupPlan,
    /// One slot per request; drained into the caller's vector in order.
    slots: Vec<Option<Result<TwinResponse>>>,
    /// Valid request indices of the current group (submission order).
    members: Vec<usize>,
    /// First lane slot of each valid request within the group's flat
    /// batch (an ensemble request occupies `lanes()` consecutive slots).
    lane_base: Vec<usize>,
    /// Per-lane stimulus staging (driven twins only; ensemble members
    /// replicate their request's stimulus).
    waves: Vec<Waveform>,
    /// Flat `[lanes * dim]` initial states of the current group (ensemble
    /// members replicate their request's h0).
    h0s: Vec<f64>,
    /// Per-request resolved noise seeds (echoed in the responses; an
    /// ensemble's members derive from it via [`ensemble_member_seed`]).
    seeds: Vec<u64>,
    /// Per-lane noise lanes (one per trajectory, rebuilt from seeds).
    lanes: Vec<NoiseLane>,
    /// Flat batched rollout output (rows = one lockstep sample).
    flat: Trajectory,
    /// Response-trajectory pool (refilled via [`DynamicsTwin::recycle`]).
    pool: TrajectoryPool,
    /// Streaming ensemble moment accumulator (pooled output buffers).
    acc: EnsembleAccumulator,
    /// Recycled [`EnsembleStats`] container shells.
    ens_shells: Vec<EnsembleStats>,
    solver: CoreSolverScratch,
}

/// Digital-backend solver scratch (stage buffers + stacked drive rows).
struct CoreSolverScratch {
    rk4: Rk4,
    u: Vec<f64>,
}

impl Default for CoreSolverScratch {
    fn default() -> Self {
        Self { rk4: Rk4::new(0), u: Vec::new() }
    }
}

/// The generic twin: a [`TwinSpec`] executed on a [`CoreBackend`]. Every
/// registered route is an instance of this type (the HP and Lorenz96
/// twins wrap it to keep their historical constructor surfaces).
pub struct DynamicsTwin {
    pub(crate) spec: TwinSpec,
    pub(crate) backend: CoreBackend,
    /// Auto-seed source for requests without an explicit noise seed.
    seeds: SeedSequencer,
    scratch: CoreScratch,
}

impl DynamicsTwin {
    /// Assemble a twin from its spec, backend and auto-seed root.
    pub fn new(
        spec: TwinSpec,
        backend: CoreBackend,
        lane_root: u64,
    ) -> Self {
        Self {
            spec,
            backend,
            seeds: SeedSequencer::new(lane_root),
            scratch: CoreScratch::default(),
        }
    }

    /// The aging analogue deployment, if this twin was built on mortal
    /// hardware (`AnalogMlp::deploy_aging`).
    fn aging_mlp(&mut self) -> Option<&mut AnalogMlp> {
        match &mut self.backend {
            CoreBackend::Analog(ode) if ode.mlp.is_aging() => {
                Some(&mut ode.mlp)
            }
            _ => None,
        }
    }

    /// Whether this twin runs on mortal (aging) analogue hardware.
    pub fn is_aging(&self) -> bool {
        matches!(
            &self.backend,
            CoreBackend::Analog(ode) if ode.mlp.is_aging()
        )
    }

    /// Advance the hardware's virtual clock by `dt_s` seconds (drift +
    /// diffusion on every cell, engines refreshed). No-op for `dt_s <= 0`;
    /// panics on a non-aging twin.
    pub fn advance_age(&mut self, dt_s: f64) {
        self.aging_mlp()
            .expect("advance_age requires an analog_aging twin")
            .advance_age(dt_s);
    }

    /// Reprogram every array back to its target weights; returns the
    /// write-verify pulse count (energy via
    /// [`crate::energy::recalibration_energy`]).
    pub fn recalibrate(&mut self) -> u64 {
        self.aging_mlp()
            .expect("recalibrate requires an analog_aging twin")
            .recalibrate()
    }

    /// Virtual device age (s); 0 for immortal twins.
    pub fn age_s(&self) -> f64 {
        match &self.backend {
            CoreBackend::Analog(ode) => ode.mlp.age_s(),
            _ => 0.0,
        }
    }

    /// Healthy-cell fraction across every deployed array (1.0 if
    /// immortal).
    pub fn array_health(&self) -> f64 {
        match &self.backend {
            CoreBackend::Analog(ode) => ode.mlp.array_health(),
            _ => 1.0,
        }
    }

    /// Lifetime write-verify pulses spent on recalibration.
    pub fn lifetime_pulses(&self) -> u64 {
        match &self.backend {
            CoreBackend::Analog(ode) => ode.mlp.lifetime_pulses(),
            _ => 0,
        }
    }

    /// Completed recalibration count.
    pub fn recalibrations(&self) -> u64 {
        match &self.backend {
            CoreBackend::Analog(ode) => ode.mlp.recalibrations(),
            _ => 0,
        }
    }

    /// Mark a random `fraction` of cells stuck (fault-injection
    /// campaigns; deterministic in the deployment's aging stream). Panics
    /// on a non-aging twin.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        self.aging_mlp()
            .expect("inject_stuck_faults requires an analog_aging twin")
            .inject_stuck_faults(fraction);
    }

    /// Per-shard serving counters of the fan-out backend, if sharded.
    pub fn shard_telemetry(&self) -> Option<Vec<ShardSnapshot>> {
        match &self.backend {
            CoreBackend::AnalogSharded(ode) => {
                Some(ode.telemetry().snapshot())
            }
            _ => None,
        }
    }

    /// Wire the fan-out backend's rollout counters into the coordinator's
    /// serving telemetry (no-op for unsharded backends).
    pub fn attach_coordinator_telemetry(
        &mut self,
        t: std::sync::Arc<crate::coordinator::telemetry::Telemetry>,
    ) {
        if let CoreBackend::AnalogSharded(ode) = &mut self.backend {
            ode.attach_coordinator_telemetry(t);
        }
    }

    /// Toggle co-scheduled group execution on the fan-out backend:
    /// batched dispatches fuse their compatible sub-batch groups into one
    /// barrier schedule ([`ShardedAnalogOde::solve_groups_into`]). No-op
    /// for unsharded backends.
    pub fn set_coschedule(&mut self, on: bool) {
        if let CoreBackend::AnalogSharded(ode) = &mut self.backend {
            ode.set_coschedule(on);
        }
    }

    /// Return a response's trajectory buffers to the twin's pool
    /// (ensemble responses hand back every stats trajectory plus the
    /// emptied container shell).
    ///
    /// Optional: callers that hand responses back make the next
    /// `run_batch` draw its output trajectories from the pool instead of
    /// the allocator — the zero-allocation steady state the allocation
    /// test (`rust/tests/alloc.rs`) pins down.
    pub fn recycle(&mut self, mut resp: TwinResponse) {
        if let Some(mut ens) = resp.ensemble.take() {
            ens.reclaim(&mut self.scratch.pool);
            self.scratch.ens_shells.push(ens);
        }
        self.scratch.pool.put(resp.trajectory);
    }

    /// Roll out the twin from `h0` for `n_points` samples (with the
    /// stimulus for driven twins). Noise draws come from the next
    /// auto-derived lane; use [`Twin::run`] with a seeded request for
    /// replayable rollouts.
    pub fn simulate(
        &mut self,
        wave: Option<Waveform>,
        h0: &[f64],
        n_points: usize,
    ) -> Result<Trajectory> {
        let mut lane = NoiseLane::from_seed(self.seeds.next_seed());
        self.simulate_lane(wave, h0, n_points, &mut lane)
    }

    /// [`DynamicsTwin::simulate`] drawing noise from an explicit
    /// trajectory lane — the replayable request path.
    fn simulate_lane(
        &mut self,
        wave: Option<Waveform>,
        h0: &[f64],
        n_points: usize,
        lane: &mut NoiseLane,
    ) -> Result<Trajectory> {
        let dim = self.spec.dim;
        let dt = self.spec.dt;
        let substeps = self.spec.digital_substeps;
        let label = self.spec.field_label;
        match &mut self.backend {
            CoreBackend::Analog(ode) => {
                let mut out = Trajectory::new(dim);
                match wave {
                    Some(w) => ode.solve_into(
                        h0,
                        &mut |t, x: &mut [f64]| x[0] = w.eval(t),
                        dt,
                        n_points,
                        lane,
                        &mut out,
                    ),
                    None => ode.solve_into(
                        h0,
                        &mut |_t, _x: &mut [f64]| {},
                        dt,
                        n_points,
                        lane,
                        &mut out,
                    ),
                }
                Ok(out)
            }
            CoreBackend::AnalogSharded(ode) => {
                let mut out = Trajectory::new(dim);
                ode.solve_into(h0, dt, n_points, lane, &mut out);
                Ok(out)
            }
            CoreBackend::Digital(DigitalModel::Mlp(mlp)) => match wave {
                Some(w) => {
                    let mut field = DrivenMlpField::new(
                        mlp,
                        move |t| w.eval(t),
                        label,
                    );
                    Ok(rk4::solve(&mut field, h0, dt, n_points, substeps))
                }
                None => {
                    let mut field = MlpField { mlp, label };
                    Ok(rk4::solve(&mut field, h0, dt, n_points, substeps))
                }
            },
            CoreBackend::Digital(DigitalModel::Field(field)) => {
                let mut f = SerialDynField { field: &**field, label };
                Ok(rk4::solve(&mut f, h0, dt, n_points, substeps))
            }
            CoreBackend::Recurrent(cell) => {
                Ok(Trajectory::from_nested(&cell.rollout(h0, n_points)))
            }
            CoreBackend::Resnet(resnet) => {
                let w = wave.ok_or_else(|| {
                    anyhow!("resnet backend requires a stimulus")
                })?;
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| vec![w.eval(k as f64 * dt)])
                    .collect();
                Ok(Trajectory::from_nested(&resnet.rollout(h0, &xs)))
            }
            CoreBackend::Pjrt(rollout) => match wave {
                Some(w) => {
                    let xs_half = w.sample_half_steps(n_points, dt);
                    Ok(Trajectory::from_nested(&rollout(
                        h0,
                        Some(&xs_half),
                    )?))
                }
                None => Ok(Trajectory::from_nested(&rollout(h0, None)?)),
            },
        }
    }

    /// Batched rollout of one compatible sub-batch into `out` (flat rows
    /// of width `batch * dim`; shared `n_points`, per-trajectory initial
    /// states stacked in `h0s`, per-lane stimuli in `waves` for driven
    /// twins). Analog and Digital backends are allocation-free with warm
    /// scratch — one multi-vector device read / per-layer GEMM per step
    /// for the whole batch; Recurrent and Resnet run their true batched
    /// rollouts with staging allocations. Per-trajectory noise lanes ⇒
    /// bit-identical to serial, noise on or off. Pjrt is handled by the
    /// caller's serial fallback.
    #[allow(clippy::too_many_arguments)]
    fn simulate_batch_flat(
        &mut self,
        waves: &[Waveform],
        h0s: &[f64],
        batch: usize,
        n_points: usize,
        solver: &mut CoreSolverScratch,
        lanes: &mut [NoiseLane],
        out: &mut Trajectory,
    ) -> Result<()> {
        let dim = self.spec.dim;
        debug_assert_eq!(h0s.len(), batch * dim);
        let dt = self.spec.dt;
        let substeps = self.spec.digital_substeps;
        let label = self.spec.field_label;
        let driven = !waves.is_empty();
        match &mut self.backend {
            CoreBackend::Analog(ode) => {
                if driven {
                    ode.solve_batch_into(
                        h0s,
                        batch,
                        &mut |b, t, x: &mut [f64]| {
                            x[0] = waves[b].eval(t)
                        },
                        dt,
                        n_points,
                        lanes,
                        out,
                    );
                } else {
                    ode.solve_batch_into(
                        h0s,
                        batch,
                        &mut |_b, _t, _x: &mut [f64]| {},
                        dt,
                        n_points,
                        lanes,
                        out,
                    );
                }
                Ok(())
            }
            CoreBackend::AnalogSharded(ode) => {
                ode.solve_batch_into(h0s, batch, dt, n_points, lanes, out);
                Ok(())
            }
            CoreBackend::Digital(DigitalModel::Mlp(mlp)) => {
                if driven {
                    let mut field = BatchDrivenMlpField::new(
                        mlp,
                        batch,
                        |b, t| waves[b].eval(t),
                        &mut solver.u,
                        label,
                    );
                    rk4::solve_batch_into(
                        &mut field,
                        h0s,
                        dt,
                        n_points,
                        substeps,
                        &mut solver.rk4,
                        out,
                    );
                } else {
                    let mut field = BatchMlpField { mlp, batch, label };
                    rk4::solve_batch_into(
                        &mut field,
                        h0s,
                        dt,
                        n_points,
                        substeps,
                        &mut solver.rk4,
                        out,
                    );
                }
                Ok(())
            }
            CoreBackend::Digital(DigitalModel::Field(field)) => {
                let mut bf =
                    BatchDynField { field: &**field, batch, label };
                rk4::solve_batch_into(
                    &mut bf,
                    h0s,
                    dt,
                    n_points,
                    substeps,
                    &mut solver.rk4,
                    out,
                );
                Ok(())
            }
            CoreBackend::Recurrent(cell) => {
                let h0_nested: Vec<Vec<f64>> = (0..batch)
                    .map(|b| h0s[b * dim..(b + 1) * dim].to_vec())
                    .collect();
                let trajs = cell.rollout_batch(&h0_nested, n_points);
                out.reset(batch * dim);
                out.reserve_rows(n_points.max(1));
                for k in 0..trajs.first().map_or(0, Vec::len) {
                    out.push_row_from_iter((0..batch).flat_map(|b| {
                        trajs[b][k].iter().copied()
                    }));
                }
                Ok(())
            }
            CoreBackend::Resnet(resnet) => {
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| {
                        waves
                            .iter()
                            .map(|w| w.eval(k as f64 * dt))
                            .collect()
                    })
                    .collect();
                let trajs = resnet.rollout_batch(h0s, batch, &xs);
                out.reset(batch * dim);
                out.reserve_rows(n_points.max(1));
                for k in 0..trajs.first().map_or(0, Vec::len) {
                    out.push_row_from_iter((0..batch).flat_map(|b| {
                        trajs[b][k].iter().copied()
                    }));
                }
                Ok(())
            }
            CoreBackend::Pjrt(_) => {
                unreachable!("pjrt uses the serial fallback")
            }
        }
    }

    /// Co-scheduled batched execution for the fan-out backend: stage
    /// *every* compatible sub-batch group first, then run them all
    /// through one fused fan-out
    /// ([`ShardedAnalogOde::solve_groups_into`]) instead of one thread
    /// scope (and one barrier schedule) per group. Request validation,
    /// seed-resolution order, lane derivation and response assembly match
    /// `run_batch_into` exactly, so responses are bit-identical with the
    /// toggle on or off. Staging is per-group owned storage — the
    /// co-scheduled path sits outside the zero-allocation contract, like
    /// the fan-out itself.
    fn run_batch_coscheduled(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        struct Stage {
            members: Vec<usize>,
            lane_base: Vec<usize>,
            h0s: Vec<f64>,
            seeds: Vec<u64>,
            lanes: Vec<NoiseLane>,
            n_points: usize,
            flat: Trajectory,
        }
        let backend = self.backend.label();
        let dim = self.spec.dim;
        let dt = self.spec.dt;
        let driven =
            matches!(self.spec.stimulus, StimulusKind::DrivenScalar);
        let mut sc = std::mem::take(&mut self.scratch);
        sc.plan.plan_lanes(reqs, MAX_SUB_BATCH_LANES);
        sc.slots.clear();
        sc.slots.resize_with(reqs.len(), || None);
        let mut stages: Vec<Stage> = Vec::new();
        for g in 0..sc.plan.n_groups() {
            let n_points = reqs[sc.plan.group(g)[0]].n_points;
            let mut st = Stage {
                members: Vec::new(),
                lane_base: Vec::new(),
                h0s: Vec::new(),
                seeds: Vec::new(),
                lanes: Vec::new(),
                n_points,
                flat: Trajectory::new(dim),
            };
            let mut lane_count = 0;
            for &i in sc.plan.group(g) {
                if driven && reqs[i].stimulus.is_none() {
                    sc.slots[i] = Some(Err(anyhow!(
                        "{} twin requires a stimulus",
                        self.spec.name
                    )));
                    continue;
                }
                let h0: &[f64] = if reqs[i].h0.is_empty() {
                    &self.spec.default_h0
                } else {
                    &reqs[i].h0
                };
                if h0.len() != dim {
                    sc.slots[i] = Some(Err(anyhow!(
                        "h0 dim {} != twin dim {}",
                        h0.len(),
                        dim
                    )));
                    continue;
                }
                if let Some(spec) = &reqs[i].ensemble {
                    if let Err(e) = spec.validate() {
                        sc.slots[i] = Some(Err(e));
                        continue;
                    }
                }
                st.members.push(i);
                st.lane_base.push(lane_count);
                for _ in 0..reqs[i].lanes() {
                    st.h0s.extend_from_slice(h0);
                }
                lane_count += reqs[i].lanes();
            }
            // Seeds and lanes in a second pass: the sequencer lives on
            // `self`, which the default-h0 borrow above keeps off-limits.
            for &i in &st.members {
                let seed = self.seeds.resolve(reqs[i].seed);
                st.seeds.push(seed);
                if reqs[i].ensemble.is_some() {
                    for m in 0..reqs[i].lanes() {
                        st.lanes.push(NoiseLane::from_seed(
                            ensemble_member_seed(seed, m as u64),
                        ));
                    }
                } else {
                    st.lanes.push(NoiseLane::from_seed(seed));
                }
            }
            if !st.members.is_empty() {
                stages.push(st);
            }
        }
        match &mut self.backend {
            CoreBackend::AnalogSharded(ode) => {
                let mut groups: Vec<ShardGroup<'_>> = stages
                    .iter_mut()
                    .map(|st| ShardGroup {
                        h0s: &st.h0s,
                        batch: st.lanes.len(),
                        dt_out: dt,
                        n_points: st.n_points,
                        lanes: &mut st.lanes,
                        out: &mut st.flat,
                    })
                    .collect();
                ode.solve_groups_into(&mut groups);
            }
            _ => unreachable!(
                "co-scheduled path requires the sharded backend"
            ),
        }
        for st in &stages {
            let batch = st.lanes.len();
            for (k, &i) in st.members.iter().enumerate() {
                let base = st.lane_base[k];
                match &reqs[i].ensemble {
                    None => {
                        let mut t = sc.pool.get(dim);
                        unbatch_into(&st.flat, batch, dim, base, &mut t);
                        sc.slots[i] = Some(Ok(TwinResponse {
                            trajectory: t,
                            backend,
                            seed: st.seeds[k],
                            ensemble: None,
                            degraded: false,
                        }));
                    }
                    Some(spec) => {
                        let shell =
                            sc.ens_shells.pop().unwrap_or_default();
                        let (t, stats) = assemble_ensemble_stats(
                            spec,
                            &st.flat,
                            crate::twin::EnsembleSlot { batch, dim, base },
                            &mut sc.acc,
                            &mut sc.pool,
                            shell,
                        );
                        sc.slots[i] = Some(Ok(TwinResponse {
                            trajectory: t,
                            backend,
                            seed: st.seeds[k],
                            ensemble: Some(stats),
                            degraded: false,
                        }));
                    }
                }
            }
        }
        for s in sc.slots.drain(..) {
            out.push(s.expect("every request receives a result"));
        }
        self.scratch = sc;
    }
}

impl Twin for DynamicsTwin {
    fn name(&self) -> &str {
        self.spec.name
    }

    fn state_dim(&self) -> usize {
        self.spec.dim
    }

    fn dt(&self) -> f64 {
        self.spec.dt
    }

    fn default_h0(&self) -> Vec<f64> {
        self.spec.default_h0.clone()
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        if req.ensemble.is_some() {
            // Ensembles always execute as one batched rollout, even when
            // submitted serially (one request = one sub-batch of N lanes).
            let mut out = Vec::with_capacity(1);
            self.run_batch_into(std::slice::from_ref(req), &mut out);
            return out.pop().expect("one result per request");
        }
        let wave = match self.spec.stimulus {
            StimulusKind::DrivenScalar => {
                Some(req.stimulus.ok_or_else(|| {
                    anyhow!(
                        "{} twin requires a stimulus",
                        self.spec.name
                    )
                })?)
            }
            StimulusKind::Autonomous => None,
        };
        // The default-h0 copy keeps `self` free for the mutable simulate
        // call below; the batched path stages initial states without it.
        let default_h0;
        let h0: &[f64] = if req.h0.is_empty() {
            default_h0 = self.spec.default_h0.clone();
            &default_h0
        } else {
            &req.h0
        };
        anyhow::ensure!(
            h0.len() == self.spec.dim,
            "h0 dim {} != twin dim {}",
            h0.len(),
            self.spec.dim
        );
        let backend = self.backend.label();
        let seed = self.seeds.resolve(req.seed);
        let mut lane = NoiseLane::from_seed(seed);
        let trajectory =
            self.simulate_lane(wave, h0, req.n_points, &mut lane)?;
        Ok(TwinResponse {
            trajectory,
            backend,
            seed,
            ensemble: None,
            degraded: false,
        })
    }

    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        let mut out = Vec::with_capacity(reqs.len());
        self.run_batch_into(reqs, &mut out);
        out
    }

    /// Batched execution: requests split into compatible sub-batches
    /// (same `n_points`, lane-counted capacity); stimuli and initial
    /// states are resolved per request, and a request with a missing
    /// stimulus, the wrong h0 dimension or an invalid ensemble spec fails
    /// alone without poisoning the rest. An ensemble request expands into
    /// `EnsembleSpec::members` noise lanes (member `k` seeded by
    /// [`ensemble_member_seed`]) inside the group's single batched
    /// rollout — including the tile-sharded execution forms — and its
    /// response carries pooled [`EnsembleStats`].
    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        if let CoreBackend::AnalogSharded(ode) = &self.backend {
            if ode.coschedule() {
                return self.run_batch_coscheduled(reqs, out);
            }
        }
        let backend = self.backend.label();
        let dim = self.spec.dim;
        let driven =
            matches!(self.spec.stimulus, StimulusKind::DrivenScalar);
        let mut sc = std::mem::take(&mut self.scratch);
        sc.plan.plan_lanes(reqs, MAX_SUB_BATCH_LANES);
        sc.slots.clear();
        sc.slots.resize_with(reqs.len(), || None);
        for g in 0..sc.plan.n_groups() {
            let n_points = reqs[sc.plan.group(g)[0]].n_points;
            sc.members.clear();
            sc.lane_base.clear();
            sc.waves.clear();
            sc.h0s.clear();
            sc.seeds.clear();
            sc.lanes.clear();
            let mut lane_count = 0;
            for &i in sc.plan.group(g) {
                let wave = match (driven, reqs[i].stimulus) {
                    (true, Some(w)) => Some(w),
                    (true, None) => {
                        sc.slots[i] = Some(Err(anyhow!(
                            "{} twin requires a stimulus",
                            self.spec.name
                        )));
                        continue;
                    }
                    (false, _) => None,
                };
                let h0: &[f64] = if reqs[i].h0.is_empty() {
                    &self.spec.default_h0
                } else {
                    &reqs[i].h0
                };
                if h0.len() != dim {
                    sc.slots[i] = Some(Err(anyhow!(
                        "h0 dim {} != twin dim {}",
                        h0.len(),
                        dim
                    )));
                    continue;
                }
                if let Some(spec) = &reqs[i].ensemble {
                    if let Err(e) = spec.validate() {
                        sc.slots[i] = Some(Err(e));
                        continue;
                    }
                }
                sc.members.push(i);
                sc.lane_base.push(lane_count);
                for _ in 0..reqs[i].lanes() {
                    sc.h0s.extend_from_slice(h0);
                    if let Some(w) = wave {
                        sc.waves.push(w);
                    }
                }
                lane_count += reqs[i].lanes();
            }
            // Seeds and lanes in a second pass: the sequencer lives on
            // `self`, which the default-h0 borrow above keeps off-limits.
            for &i in &sc.members {
                let seed = self.seeds.resolve(reqs[i].seed);
                sc.seeds.push(seed);
                if reqs[i].ensemble.is_some() {
                    for m in 0..reqs[i].lanes() {
                        sc.lanes.push(NoiseLane::from_seed(
                            ensemble_member_seed(seed, m as u64),
                        ));
                    }
                } else {
                    sc.lanes.push(NoiseLane::from_seed(seed));
                }
            }
            if sc.members.is_empty() {
                continue;
            }
            let batch = sc.lanes.len();
            if matches!(self.backend, CoreBackend::Pjrt(_)) {
                // No batched artifact path yet: per-trajectory rollouts
                // (and therefore no single-rollout ensemble expansion).
                for k in 0..sc.members.len() {
                    let i = sc.members[k];
                    if reqs[i].ensemble.is_some() {
                        sc.slots[i] = Some(Err(anyhow!(
                            "ensemble requests are not supported on the \
                             pjrt backend"
                        )));
                        continue;
                    }
                    let base = sc.lane_base[k];
                    let seed = sc.seeds[k];
                    let wave =
                        if driven { Some(sc.waves[base]) } else { None };
                    let r = self
                        .simulate_lane(
                            wave,
                            &sc.h0s[base * dim..(base + 1) * dim],
                            n_points,
                            &mut sc.lanes[base],
                        )
                        .map(|trajectory| TwinResponse {
                            trajectory,
                            backend,
                            seed,
                            ensemble: None,
                            degraded: false,
                        });
                    sc.slots[i] = Some(r);
                }
                continue;
            }
            match self.simulate_batch_flat(
                &sc.waves,
                &sc.h0s,
                batch,
                n_points,
                &mut sc.solver,
                &mut sc.lanes,
                &mut sc.flat,
            ) {
                Ok(()) => {
                    for (k, &i) in sc.members.iter().enumerate() {
                        let base = sc.lane_base[k];
                        match &reqs[i].ensemble {
                            None => {
                                let mut t = sc.pool.get(dim);
                                unbatch_into(
                                    &sc.flat, batch, dim, base, &mut t,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: None,
                                    degraded: false,
                                }));
                            }
                            Some(spec) => {
                                let shell = sc
                                    .ens_shells
                                    .pop()
                                    .unwrap_or_default();
                                let (t, stats) = assemble_ensemble_stats(
                                    spec,
                                    &sc.flat,
                                    crate::twin::EnsembleSlot {
                                        batch,
                                        dim,
                                        base,
                                    },
                                    &mut sc.acc,
                                    &mut sc.pool,
                                    shell,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: Some(stats),
                                    degraded: false,
                                }));
                            }
                        }
                    }
                }
                Err(e) => {
                    // Group-level failure: broadcast without touching
                    // other groups.
                    let msg = format!("{e:#}");
                    for &i in &sc.members {
                        sc.slots[i] =
                            Some(Err(anyhow!(msg.clone())));
                    }
                }
            }
        }
        for s in sc.slots.drain(..) {
            out.push(s.expect("every request receives a result"));
        }
        self.scratch = sc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Element-wise decay, the shared analytic fixture: dx/dt = -x.
    struct Decay {
        dim: usize,
    }

    impl DynField for Decay {
        fn dim(&self) -> usize {
            self.dim
        }

        fn eval_into(&self, _t: f64, x: &[f64], out: &mut [f64]) {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = -v;
            }
        }
    }

    fn decay_twin(dim: usize) -> DynamicsTwin {
        DynamicsTwin::new(
            TwinSpec {
                name: "decay",
                field_label: "decay/digital",
                dim,
                dt: 0.05,
                default_h0: vec![1.0; dim],
                stimulus: StimulusKind::Autonomous,
                digital_substeps: 1,
            },
            CoreBackend::Digital(DigitalModel::Field(Box::new(Decay {
                dim,
            }))),
            7,
        )
    }

    #[test]
    fn dyn_field_twin_solves_and_uses_default_h0() {
        let mut twin = decay_twin(3);
        assert_eq!(twin.name(), "decay");
        assert_eq!(twin.state_dim(), 3);
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 41)).unwrap();
        assert_eq!(resp.backend, "digital-rk4");
        assert_eq!(resp.trajectory.row(0), [1.0, 1.0, 1.0]);
        let last = resp.trajectory.last().unwrap();
        let exact = (-2.0f64).exp();
        for &v in last {
            assert!((v - exact).abs() < 1e-5, "decay err {v}");
        }
    }

    #[test]
    fn dyn_field_batch_bit_identical_to_serial() {
        let mut twin = decay_twin(2);
        let reqs = vec![
            TwinRequest::autonomous(vec![1.0, -2.0], 20),
            TwinRequest::autonomous(vec![0.25, 0.5], 9),
            TwinRequest::autonomous(vec![], 20),
        ];
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
        }
        // Warm pass with recycling: pooled buffers stay clean.
        for (resp, s) in twin.run_batch(&reqs).into_iter().zip(&serial) {
            let resp = resp.unwrap();
            assert_eq!(resp.trajectory, s.trajectory);
            twin.recycle(resp);
        }
        let third = twin.run_batch(&reqs);
        for (b, s) in third.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap().trajectory, s.trajectory);
        }
    }

    #[test]
    fn dyn_field_twin_rejects_bad_h0_dim_per_request() {
        let mut twin = decay_twin(3);
        let results = twin.run_batch(&[
            TwinRequest::autonomous(vec![1.0, 2.0, 3.0], 5),
            TwinRequest::autonomous(vec![1.0], 5),
            TwinRequest::autonomous(vec![0.5, 0.5, 0.5], 5),
        ]);
        assert!(results[0].is_ok());
        let err = results[1].as_ref().err().unwrap().to_string();
        assert!(err.contains("h0 dim 1 != twin dim 3"), "{err}");
        assert!(results[2].is_ok());
    }

    #[test]
    fn driven_spec_requires_stimulus() {
        let mut twin = DynamicsTwin::new(
            TwinSpec {
                name: "driven-decay",
                field_label: "driven-decay/digital",
                dim: 1,
                dt: 0.05,
                default_h0: vec![1.0],
                stimulus: StimulusKind::DrivenScalar,
                digital_substeps: 1,
            },
            CoreBackend::Digital(DigitalModel::Field(Box::new(Decay {
                dim: 1,
            }))),
            7,
        );
        let err = twin
            .run(&TwinRequest::autonomous(vec![], 5))
            .err()
            .unwrap()
            .to_string();
        assert!(
            err.contains("driven-decay twin requires a stimulus"),
            "{err}"
        );
    }

    #[test]
    fn dyn_field_ensemble_members_replay_standalone() {
        use crate::twin::EnsembleSpec;
        let mut twin = decay_twin(2);
        let req = TwinRequest::autonomous(vec![0.5, -0.5], 6)
            .with_seed(99)
            .with_ensemble(
                EnsembleSpec::new(4).with_member_trajectories(),
            );
        let resp = twin.run(&req).unwrap();
        let ens = resp.ensemble.as_ref().unwrap();
        assert_eq!(ens.members, 4);
        for (k, member) in ens.member_trajectories.iter().enumerate() {
            let standalone = twin
                .run(
                    &TwinRequest::autonomous(vec![0.5, -0.5], 6)
                        .with_seed(ensemble_member_seed(99, k as u64)),
                )
                .unwrap();
            assert_eq!(*member, standalone.trajectory, "member {k}");
        }
    }
}
