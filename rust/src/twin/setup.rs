//! Application wiring: trained weights + config -> a fully-populated
//! [`TwinRegistry`]. Shared by the `memode` CLI, the examples and the
//! benches so every entry point sees the same route table:
//!
//! | route                | backend                              |
//! |----------------------|--------------------------------------|
//! | `hp/analog`          | memristive solver (simulated chip)   |
//! | `hp/analog-aged`     | aging crossbar behind the health monitor |
//! | `hp/digital`         | Rust RK4 on the trained field        |
//! | `hp/resnet`          | recurrent-ResNet baseline            |
//! | `hp/pjrt`            | AOT HLO rollout via PJRT             |
//! | `lorenz96/analog`    | memristive solver                    |
//! | `lorenz96/analog-sharded` | memristive solver, tile-sharded fan-out |
//! | `lorenz96/analog-aged` | aging crossbar behind the health monitor |
//! | `lorenz96/digital`   | Rust RK4                             |
//! | `lorenz96/rnn|gru|lstm` | recurrent baselines               |
//! | `lorenz96/pjrt`      | AOT HLO rollout via PJRT             |
//! | `kuramoto/digital`   | RK4 on the closed-form coupled-oscillator field |
//! | `l96two/digital`     | RK4 on the closed-form two-level Lorenz96 field |
//!
//! Every route is registered with a [`RouteInfo`] (dim, dt, backend
//! family, aged/synthetic flags): `memode serve` prints the table at
//! startup, `unknown_route` wire errors enumerate it, and the router
//! validates request `y0` dimensions against it before admission.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::device::taox::DeviceConfig;
use crate::models::loader::{
    load_mlp_weights, load_rnn_weights, MlpWeights, RnnWeights,
};
use crate::runtime::artifacts::{
    autonomous_rollout_fn, driven_rollout_fn, ArtifactManifest,
};
use crate::runtime::service::PjrtHandle;
use crate::twin::hp::HpTwin;
use crate::twin::lorenz96::Lorenz96Twin;
use crate::twin::registry::{RouteInfo, TwinRegistry};
use crate::twin::{kuramoto, l96two};

/// All trained weights from `artifacts/weights/`.
#[derive(Debug, Clone)]
pub struct TrainedWeights {
    pub hp_node: Arc<MlpWeights>,
    pub hp_resnet: Arc<MlpWeights>,
    pub l96_node: Arc<MlpWeights>,
    pub l96_rnn: Arc<RnnWeights>,
    pub l96_gru: Arc<RnnWeights>,
    pub l96_lstm: Arc<RnnWeights>,
}

impl TrainedWeights {
    /// Load every weight file the training pipeline exports.
    pub fn load(cfg: &SystemConfig) -> Result<Self> {
        let wdir = cfg.artifacts_dir.join("weights");
        let mlp = |name: &str| -> Result<Arc<MlpWeights>> {
            load_mlp_weights(&wdir.join(format!("{name}.json")))
                .with_context(|| format!("loading {name} (run `make artifacts`)"))
                .map(Arc::new)
        };
        let rnn = |name: &str| -> Result<Arc<RnnWeights>> {
            load_rnn_weights(&wdir.join(format!("{name}.json")))
                .with_context(|| format!("loading {name} (run `make artifacts`)"))
                .map(Arc::new)
        };
        Ok(Self {
            hp_node: mlp("hp_node")?,
            hp_resnet: mlp("hp_resnet")?,
            l96_node: mlp("l96_node")?,
            l96_rnn: rnn("l96_rnn")?,
            l96_gru: rnn("l96_gru")?,
            l96_lstm: rnn("l96_lstm")?,
        })
    }
}

/// Immortal-route metadata shorthand (aged/synthetic flags default off).
fn info(dim: usize, dt: f64, backend: &'static str) -> RouteInfo {
    RouteInfo { dim, dt, backend, aged: false, synthetic: false }
}

/// Register the closed-form analytic worlds. They need no trained
/// artifacts (the vector fields are exact), so both the production and
/// the synthetic registry carry them — each is one [`DynField`]
/// (`crate::twin::core::DynField`) impl plus this stanza.
fn register_analytic_worlds(reg: &mut TwinRegistry) {
    reg.register_info(
        "kuramoto/digital",
        RouteInfo {
            synthetic: true,
            ..info(kuramoto::DIM, kuramoto::DT, "digital-rk4")
        },
        || Box::new(kuramoto::twin()),
    );
    reg.register_info(
        "l96two/digital",
        RouteInfo {
            synthetic: true,
            ..info(l96two::DIM, l96two::DT, "digital-rk4")
        },
        || Box::new(l96two::twin()),
    );
}

/// Build the route table. `pjrt` is optional: CPU-only flows (device
/// characterisation, analogue-only experiments) work without artifacts
/// compiled into a PJRT service.
pub fn build_registry(
    cfg: &SystemConfig,
    weights: &TrainedWeights,
    pjrt: Option<PjrtHandle>,
) -> Result<TwinRegistry> {
    build_registry_with_telemetry(cfg, weights, pjrt, None)
}

/// [`build_registry`] with the coordinator's serving telemetry: the
/// tile-sharded route's shard workers report `shard_rollouts` /
/// `shard_steps` into it. Pass the same instance to
/// [`crate::coordinator::service::Coordinator::start_with_telemetry`] so
/// sharded load shows up in the served metrics (the serve CLI does).
pub fn build_registry_with_telemetry(
    cfg: &SystemConfig,
    weights: &TrainedWeights,
    pjrt: Option<PjrtHandle>,
    telemetry: Option<Arc<crate::coordinator::telemetry::Telemetry>>,
) -> Result<TwinRegistry> {
    let mut reg = TwinRegistry::new();
    let device = cfg.device.clone();
    let noise = cfg.noise;
    let seed = cfg.seed;
    let hp_dt = weights.hp_node.dt;
    let l96_dt = weights.l96_node.dt;
    let l96_dim = weights.l96_node.layers.last().unwrap().0.cols;

    // -- HP memristor twin ------------------------------------------------
    {
        let w = Arc::clone(&weights.hp_node);
        let dev = device.clone();
        reg.register_info("hp/analog", info(1, hp_dt, "analog"), move || {
            Box::new(HpTwin::analog(&w, &dev, noise, seed))
        });
    }
    {
        // Health-monitored aging HP route: the paper's physically-deployed
        // twin on a mortal crossbar, under the same detect → recalibrate →
        // degrade loop as `lorenz96/analog-aged`. Faults stay on — yield
        // is what the lifetime loop manages.
        let w = Arc::clone(&weights.hp_node);
        let dev = device.clone();
        let tel = telemetry.clone();
        reg.register_info(
            "hp/analog-aged",
            RouteInfo { aged: true, ..info(1, hp_dt, "analog") },
            move || {
                let mut twin = crate::twin::health::MonitoredTwin::hp(
                    &w,
                    &dev,
                    noise,
                    seed,
                    crate::twin::hp::ANALOG_SUBSTEPS,
                    crate::twin::health::LifetimeConfig::default(),
                );
                if let Some(t) = &tel {
                    twin = twin
                        .with_telemetry("hp/analog-aged", Arc::clone(t));
                }
                Box::new(twin)
            },
        );
    }
    {
        let w = Arc::clone(&weights.hp_node);
        reg.register_info(
            "hp/digital",
            info(1, hp_dt, "digital-rk4"),
            move || Box::new(HpTwin::digital(&w)),
        );
    }
    {
        let w = Arc::clone(&weights.hp_resnet);
        let dt = w.dt;
        reg.register_info("hp/resnet", info(1, dt, "resnet"), move || {
            Box::new(HpTwin::resnet(&w))
        });
    }

    // -- Lorenz96 twin ----------------------------------------------------
    {
        let w = Arc::clone(&weights.l96_node);
        // The paper's Fig. 4 analogue system is an *experimentally grounded
        // simulation* (only the small HP net was physically deployed): its
        // Fig. 4j robustness axes are read and programming noise, with no
        // yield faults. Mirror that convention — faults stay on for the
        // HP twin and the Fig. 2 characterisation.
        let dev = DeviceConfig { fault_rate: 0.0, ..device.clone() };
        reg.register_info(
            "lorenz96/analog",
            info(l96_dim, l96_dt, "analog"),
            move || Box::new(Lorenz96Twin::analog(&w, &dev, noise, seed)),
        );
    }
    {
        // Tile-sharded fan-out route: the same deployment split across
        // parallel shard workers (the scheduler's tile-aware dispatch
        // mode; states wider than one array use the same path).
        let w = Arc::clone(&weights.l96_node);
        let dev = DeviceConfig { fault_rate: 0.0, ..device.clone() };
        let tel = telemetry.clone();
        let coschedule = cfg.serve.coschedule;
        reg.register_info(
            "lorenz96/analog-sharded",
            info(l96_dim, l96_dt, "analog-sharded"),
            move || {
                let mut twin = Lorenz96Twin::analog_opts(
                    &w,
                    &dev,
                    noise,
                    seed,
                    crate::twin::lorenz96::L96AnalogOpts {
                        shards: 2,
                        parallel: true,
                        ..Default::default()
                    },
                );
                twin.set_coschedule(coschedule);
                if let Some(t) = &tel {
                    twin.attach_coordinator_telemetry(Arc::clone(t));
                }
                Box::new(twin)
            },
        );
    }
    {
        // Health-monitored aging route: the same deployment on a mortal
        // crossbar. Served rollouts advance the device's virtual clock,
        // periodic probes compare against the digital reference, failing
        // probes trigger recalibration, and exhausted recalibration
        // budgets flip the route to flagged digital fallback. Faults stay
        // on here — yield is exactly what the lifetime loop manages.
        let w = Arc::clone(&weights.l96_node);
        let dev = device.clone();
        let tel = telemetry.clone();
        reg.register_info(
            "lorenz96/analog-aged",
            RouteInfo { aged: true, ..info(l96_dim, l96_dt, "analog") },
            move || {
                let mut twin =
                    crate::twin::health::MonitoredTwin::lorenz96(
                        &w,
                        &dev,
                        noise,
                        seed,
                        crate::twin::lorenz96::ANALOG_SUBSTEPS,
                        crate::twin::health::LifetimeConfig::default(),
                    );
                if let Some(t) = &tel {
                    twin = twin.with_telemetry(
                        "lorenz96/analog-aged",
                        Arc::clone(t),
                    );
                }
                Box::new(twin)
            },
        );
    }
    {
        let w = Arc::clone(&weights.l96_node);
        reg.register_info(
            "lorenz96/digital",
            info(l96_dim, l96_dt, "digital-rk4"),
            move || Box::new(Lorenz96Twin::digital(&w)),
        );
    }
    for (route, w) in [
        ("lorenz96/rnn", Arc::clone(&weights.l96_rnn)),
        ("lorenz96/gru", Arc::clone(&weights.l96_gru)),
        ("lorenz96/lstm", Arc::clone(&weights.l96_lstm)),
    ] {
        let ri = info(w.d_in, w.dt, "recurrent");
        reg.register_info(route, ri, move || {
            Box::new(
                Lorenz96Twin::recurrent(&w)
                    .expect("validated at load time"),
            )
        });
    }
    register_analytic_worlds(&mut reg);

    // -- PJRT routes (when a runtime service is up) -------------------------
    if let Some(handle) = pjrt {
        let manifest = ArtifactManifest::load(&cfg.artifacts_dir)?;
        let hp_meta = manifest.get("hp_rollout")?.clone();
        let l96_meta = manifest.get("l96_rollout")?.clone();
        {
            let h = handle.clone();
            let meta = hp_meta;
            reg.register_info(
                "hp/pjrt",
                info(1, hp_dt, "pjrt"),
                move || {
                    Box::new(HpTwin::pjrt(
                        driven_rollout_fn(h.clone(), &meta),
                        hp_dt,
                    ))
                },
            );
        }
        {
            let h = handle;
            let meta = l96_meta;
            reg.register_info(
                "lorenz96/pjrt",
                info(l96_dim, l96_dt, "pjrt"),
                move || {
                    Box::new(Lorenz96Twin::pjrt(
                        autonomous_rollout_fn(h.clone(), &meta),
                        l96_dt,
                        l96_dim,
                    ))
                },
            );
        }
    }
    Ok(reg)
}

/// A self-contained route table over **synthetic** weights — no
/// `artifacts/` on disk required. This is what `memode serve
/// --synthetic` and the CI serve-smoke job bind to a socket: the same
/// coordinator + network stack as production, exercising every serving
/// path (plain, ensemble, health-monitored aging) over fixture models:
///
/// | route                  | backend                                  |
/// |------------------------|------------------------------------------|
/// | `lorenz96/digital`     | RK4 on the decay fixture field           |
/// | `lorenz96/analog`      | quiet memristive solver (no faults)      |
/// | `lorenz96/analog-sharded` | quiet solver, tile-sharded fan-out (co-scheduling via `MEMODE_COSCHEDULE`) |
/// | `lorenz96/analog-aged` | aging crossbar behind the health monitor |
/// | `hp/digital`           | RK4 on the trained-shape HP field        |
/// | `hp/analog-aged`       | aging crossbar behind the health monitor |
/// | `kuramoto/digital`     | RK4 on the coupled-oscillator field      |
/// | `l96two/digital`       | RK4 on the two-level Lorenz96 field      |
///
/// Pass the coordinator's [`Telemetry`](crate::coordinator::telemetry)
/// so the aged route's lifetime snapshots surface in served metrics.
pub fn build_synthetic_registry(
    telemetry: Option<Arc<crate::coordinator::telemetry::Telemetry>>,
) -> TwinRegistry {
    use crate::analog::system::AnalogNoise;
    use crate::models::loader::decay_mlp_weights;
    use crate::twin::health::{LifetimeConfig, MonitoredTwin};
    use crate::twin::throughput::hp_weights;

    // Solver resolution for the synthetic analogue routes: smaller than
    // the paper-default substeps so a CI smoke run stays cheap, while
    // still driving the full crossbar read/write path.
    const SYNTH_SUBSTEPS: usize = 5;

    let mut reg = TwinRegistry::new();
    let noise = AnalogNoise { read: 0.01, prog: 0.0 };
    let seed = 42;
    let synth =
        |dim: usize, dt: f64, backend: &'static str| RouteInfo {
            synthetic: true,
            ..info(dim, dt, backend)
        };
    {
        let w = decay_mlp_weights(6);
        let dt = w.dt;
        reg.register_info(
            "lorenz96/digital",
            synth(6, dt, "digital-rk4"),
            move || Box::new(Lorenz96Twin::digital(&w)),
        );
    }
    {
        let w = decay_mlp_weights(6);
        let dt = w.dt;
        let dev = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        reg.register_info(
            "lorenz96/analog",
            synth(6, dt, "analog"),
            move || {
                Box::new(Lorenz96Twin::analog_opts(
                    &w,
                    &dev,
                    noise,
                    seed,
                    crate::twin::lorenz96::L96AnalogOpts {
                        substeps: SYNTH_SUBSTEPS,
                        ..Default::default()
                    },
                ))
            },
        );
    }
    {
        // Tile-sharded fan-out over the same quiet deployment, so the
        // serve smoke / heavy-tail mixes exercise sharded execution over
        // TCP. Co-scheduling follows the MEMODE_COSCHEDULE toggle (the
        // synthetic registry has no SystemConfig to read it from).
        let w = decay_mlp_weights(6);
        let dev = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let dt = w.dt;
        let tel = telemetry.clone();
        reg.register_info(
            "lorenz96/analog-sharded",
            synth(6, dt, "analog-sharded"),
            move || {
                let mut twin = Lorenz96Twin::analog_opts(
                    &w,
                    &dev,
                    noise,
                    seed,
                    crate::twin::lorenz96::L96AnalogOpts {
                        substeps: SYNTH_SUBSTEPS,
                        shards: 2,
                        parallel: true,
                    },
                );
                twin.set_coschedule(
                    crate::twin::shard::coschedule_from_env(),
                );
                if let Some(t) = &tel {
                    twin.attach_coordinator_telemetry(Arc::clone(t));
                }
                Box::new(twin)
            },
        );
    }
    {
        // Aging crossbar behind the health monitor: light probe cadence
        // so short smoke runs stay fast, but rollouts still age the
        // device and can trigger recalibration / degraded fallback.
        let w = decay_mlp_weights(6);
        let dt = w.dt;
        let dev = DeviceConfig::default();
        let tel = telemetry.clone();
        reg.register_info(
            "lorenz96/analog-aged",
            RouteInfo { aged: true, ..synth(6, dt, "analog") },
            move || {
                let mut twin = MonitoredTwin::lorenz96(
                    &w,
                    &dev,
                    noise,
                    seed,
                    SYNTH_SUBSTEPS,
                    LifetimeConfig {
                        age_per_rollout_s: 3600.0,
                        probe_every: 64,
                        probe_points: 8,
                        ..Default::default()
                    },
                );
                if let Some(t) = &tel {
                    twin = twin.with_telemetry(
                        "lorenz96/analog-aged",
                        Arc::clone(t),
                    );
                }
                Box::new(twin)
            },
        );
    }
    {
        let w = hp_weights();
        let dt = w.dt;
        reg.register_info(
            "hp/digital",
            synth(1, dt, "digital-rk4"),
            move || Box::new(HpTwin::digital(&w)),
        );
    }
    {
        // Aging HP route over the same trained-shape synthetic weights:
        // the driven family behind the health monitor, light probe
        // cadence for smoke runs.
        let w = hp_weights();
        let dt = w.dt;
        let dev = DeviceConfig::default();
        let tel = telemetry.clone();
        reg.register_info(
            "hp/analog-aged",
            RouteInfo { aged: true, ..synth(1, dt, "analog") },
            move || {
                let mut twin = MonitoredTwin::hp(
                    &w,
                    &dev,
                    noise,
                    seed,
                    SYNTH_SUBSTEPS,
                    LifetimeConfig {
                        age_per_rollout_s: 3600.0,
                        probe_every: 64,
                        probe_points: 8,
                        ..Default::default()
                    },
                );
                if let Some(t) = &tel {
                    twin = twin
                        .with_telemetry("hp/analog-aged", Arc::clone(t));
                }
                Box::new(twin)
            },
        );
    }
    register_analytic_worlds(&mut reg);
    reg
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        let w = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/weights");
        // All weight files must exist (a retrain may be mid-flight).
        ["hp_node", "hp_resnet", "l96_node", "l96_rnn", "l96_gru", "l96_lstm"]
            .iter()
            .all(|n| w.join(format!("{n}.json")).exists())
    }

    fn cfg() -> SystemConfig {
        SystemConfig {
            artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts"),
            ..Default::default()
        }
    }

    #[test]
    fn weights_load_if_artifacts_present() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let w = TrainedWeights::load(&cfg()).unwrap();
        assert_eq!(w.hp_node.layers.len(), 3);
        assert_eq!(w.l96_node.layers.len(), 3);
        assert_eq!(w.l96_lstm.kind, "lstm");
        assert_eq!(w.l96_lstm.hidden, 64);
    }

    #[test]
    fn registry_routes_without_pjrt() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = cfg();
        let w = TrainedWeights::load(&c).unwrap();
        let reg = build_registry(&c, &w, None).unwrap();
        for route in [
            "hp/analog",
            "hp/analog-aged",
            "hp/digital",
            "hp/resnet",
            "lorenz96/analog",
            "lorenz96/analog-sharded",
            "lorenz96/analog-aged",
            "lorenz96/digital",
            "lorenz96/rnn",
            "lorenz96/gru",
            "lorenz96/lstm",
            "kuramoto/digital",
            "l96two/digital",
        ] {
            assert!(reg.contains(route), "missing {route}");
            assert!(reg.info(route).is_some(), "no metadata for {route}");
        }
        assert!(!reg.contains("hp/pjrt"));
        let aged = reg.info("hp/analog-aged").unwrap();
        assert!(aged.aged);
        assert_eq!(aged.dim, 1);
        let kur = reg.info("kuramoto/digital").unwrap();
        assert_eq!(kur.dim, crate::twin::kuramoto::DIM);
        assert_eq!(kur.backend, "digital-rk4");
    }

    #[test]
    fn synthetic_registry_needs_no_artifacts() {
        let reg = build_synthetic_registry(None);
        for route in [
            "lorenz96/digital",
            "lorenz96/analog",
            "lorenz96/analog-sharded",
            "lorenz96/analog-aged",
            "hp/digital",
            "hp/analog-aged",
            "kuramoto/digital",
            "l96two/digital",
        ] {
            assert!(reg.contains(route), "missing {route}");
            let info = reg.info(route).expect("synthetic route metadata");
            assert!(info.synthetic, "{route} not flagged synthetic");
        }
        // Every factory must actually instantiate and serve a rollout
        // (HP is a driven twin, so its smoke request carries a stimulus).
        use crate::twin::TwinRequest;
        use crate::workload::stimuli::Waveform;
        for route in reg.keys() {
            let mut twin = reg.create(&route).unwrap();
            let req = if route.starts_with("hp/") {
                TwinRequest::driven(vec![], 4, Waveform::sine(1.0, 50.0))
            } else {
                TwinRequest::autonomous(vec![], 4)
            }
            .with_seed(7);
            let resp = twin.run(&req).unwrap();
            assert_eq!(resp.trajectory.len(), 4, "short rollout on {route}");
            assert_eq!(resp.seed, 7, "seed echo on {route}");
        }
    }

    #[test]
    fn missing_weights_error_mentions_make() {
        let c = SystemConfig {
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        };
        let err = TrainedWeights::load(&c).unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
