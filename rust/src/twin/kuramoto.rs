//! Kuramoto coupled-oscillator twin — the first analytical world added on
//! top of the generic core, proving a new twin is ~a hundred lines.
//!
//! dθ_i/dt = ω_i + (K/N) Σ_j sin(θ_j − θ_i), evaluated in O(N) through
//! the mean-field identity Σ_j sin(θ_j − θ_i) = S·cos θ_i − C·sin θ_i
//! with S = Σ sin θ_j, C = Σ cos θ_j. Above the critical coupling the
//! oscillators phase-lock; the order parameter r = |Σ e^{iθ}|/N → 1.

use crate::twin::core::{
    CoreBackend, DigitalModel, DynField, DynamicsTwin, StimulusKind,
    TwinSpec,
};

/// Default oscillator count (state dimension).
pub const DIM: usize = 16;
/// Default coupling strength (well above critical for the spread below).
pub const COUPLING: f64 = 1.5;
/// Output sample interval (s).
pub const DT: f64 = 0.05;
/// RK4 substeps per output sample.
const SUBSTEPS: usize = 2;
/// Auto-seed root for noise lanes on this twin.
const KURAMOTO_AUTO_ROOT: u64 = 0x4b52_5eed_0000_0004;

/// Deterministic natural frequencies: a bounded spread around 1 rad/s.
pub fn natural_frequencies(n: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + 0.3 * ((i as f64) * 0.83).sin()).collect()
}

/// Deterministic initial phases: golden-angle sequence over [0, 2π).
pub fn default_theta0(n: usize) -> Vec<f64> {
    let golden = 2.399_963_229_728_653;
    (0..n)
        .map(|i| (i as f64 * golden) % std::f64::consts::TAU)
        .collect()
}

/// Mean-field phase coherence r ∈ [0, 1] of a phase vector.
pub fn order_parameter(theta: &[f64]) -> f64 {
    let n = theta.len().max(1) as f64;
    let s: f64 = theta.iter().map(|t| t.sin()).sum();
    let c: f64 = theta.iter().map(|t| t.cos()).sum();
    (s * s + c * c).sqrt() / n
}

/// The Kuramoto vector field.
pub struct KuramotoField {
    omega: Vec<f64>,
    coupling: f64,
}

impl KuramotoField {
    pub fn new(dim: usize, coupling: f64) -> Self {
        Self { omega: natural_frequencies(dim), coupling }
    }
}

impl DynField for KuramotoField {
    fn dim(&self) -> usize {
        self.omega.len()
    }

    fn eval_into(&self, _t: f64, x: &[f64], out: &mut [f64]) {
        let n = x.len() as f64;
        let s: f64 = x.iter().map(|t| t.sin()).sum();
        let c: f64 = x.iter().map(|t| t.cos()).sum();
        let k = self.coupling / n;
        for i in 0..x.len() {
            out[i] = self.omega[i]
                + k * (s * x[i].cos() - c * x[i].sin());
        }
    }
}

/// The default registry twin: [`DIM`] oscillators at [`COUPLING`].
pub fn twin() -> DynamicsTwin {
    twin_with(DIM, COUPLING)
}

/// A Kuramoto twin with an explicit size and coupling.
pub fn twin_with(dim: usize, coupling: f64) -> DynamicsTwin {
    let spec = TwinSpec {
        name: "kuramoto",
        field_label: "kuramoto/digital",
        dim,
        dt: DT,
        default_h0: default_theta0(dim),
        stimulus: StimulusKind::Autonomous,
        digital_substeps: SUBSTEPS,
    };
    DynamicsTwin::new(
        spec,
        CoreBackend::Digital(DigitalModel::Field(Box::new(
            KuramotoField::new(dim, coupling),
        ))),
        KURAMOTO_AUTO_ROOT,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{Twin, TwinRequest};

    #[test]
    fn field_matches_pairwise_sum() {
        let f = KuramotoField::new(5, 1.2);
        let theta = default_theta0(5);
        let mut fast = vec![0.0; 5];
        f.eval_into(0.0, &theta, &mut fast);
        for i in 0..5 {
            let pairwise: f64 = (0..5)
                .map(|j| (theta[j] - theta[i]).sin())
                .sum::<f64>();
            let want =
                natural_frequencies(5)[i] + 1.2 / 5.0 * pairwise;
            assert!((fast[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn uncoupled_oscillators_drift_at_their_frequency() {
        let mut twin = twin_with(4, 0.0);
        let resp = twin
            .run(&TwinRequest::autonomous(vec![0.0; 4], 11))
            .unwrap();
        let omega = natural_frequencies(4);
        for (i, &w) in omega.iter().enumerate() {
            let got = resp.trajectory.row(10)[i];
            assert!(
                (got - w * 10.0 * DT).abs() < 1e-9,
                "oscillator {i}: {got} vs {}",
                w * 10.0 * DT
            );
        }
    }

    #[test]
    fn strong_coupling_synchronizes_the_population() {
        let mut twin = twin();
        let resp =
            twin.run(&TwinRequest::autonomous(vec![], 400)).unwrap();
        let r0 = order_parameter(resp.trajectory.row(0));
        let r_end =
            order_parameter(resp.trajectory.row(resp.trajectory.len() - 1));
        assert!(r0 < 0.5, "golden-angle start is incoherent, r0 = {r0}");
        assert!(r_end > 0.9, "population failed to lock, r = {r_end}");
    }
}
