//! Tile-sharded analogue execution: one trajectory's state spread across
//! several simulated crossbar tile column-groups, each driven by its own
//! shard worker.
//!
//! [`crate::analog::system::AnalogNeuralOde::with_shards`] gives the
//! solver a *serial* sharded kernel (per-shard tile reads on one thread,
//! zero-allocation warm path). This module adds the fan-out form the
//! scheduler's tile-aware dispatch uses: a [`ShardedAnalogOde`] built from
//! the same deployment, whose rollout spawns one OS thread per shard
//! (scoped to the rollout), synchronised by a [`std::sync::Barrier`] at
//! every exchange point of every circuit step:
//!
//! ```text
//!   publish state slice ── barrier ── read full state
//!   layer 0 shard read  ── publish hidden slice ── barrier ── read full
//!   ...
//!   last layer shard read ──> feed own integrator bank (no exchange)
//! ```
//!
//! Each shard worker owns a private [`VmmEngine`] per layer (the column
//! slice of the deployed engine — its tile column-group), private
//! peripheral stages, a private integrator bank for its state slice and
//! private copies of the per-trajectory noise lanes. Nothing mutable is
//! shared: shards exchange activations through per-layer mutex-guarded
//! buffers, writing disjoint column ranges and copying the full buffer out
//! after the barrier. The stitched output is **bit-identical** to the
//! monolithic solver in *every* noise mode: per-element accumulation order
//! is preserved by the column-shard kernels, and noise draws are
//! lane-indexed by full-layer column (each worker's column-shard engine
//! reads the same lane values the monolithic engine would produce for its
//! columns, and advances its lane copies by the full-layer draw count, so
//! all copies stay in lockstep — `rust/tests/sharded.rs` and
//! `rust/tests/noisy_determinism.rs` pin this down).
//!
//! The fan-out path allocates per rollout (thread spawn, first-use buffer
//! growth) and is therefore *outside* the zero-allocation contract of
//! `lib.rs`; the serial sharded kernel is the allocation-free form. The
//! fan-out exists for the capacity scenario the paper's scalability claims
//! rest on — states larger than one physical array, spread over workers —
//! not for small-state latency.
//!
//! **Co-scheduling** ([`ShardedAnalogOde::solve_groups_into`]) extends the
//! fan-out to multiple trajectories' groups at once: the sub-batches of
//! one dispatch share a single thread scope and a single fused barrier
//! sequence, so each exchange barrier's latency amortises over every
//! group's useful tile work instead of being paid once per group. Each
//! group keeps fully private state (banks, lane copies, exchange buffers),
//! which is why the fused output stays bit-identical to sequential
//! rollouts.
//!
//! The batched GEMM's multicore path
//! (`util::tensor::Mat::vecmat_batch_into` past the
//! `util::kernel::plan_threads` thresholds) reuses this module's worker
//! pattern — scoped threads over disjoint work blocks, joined before the
//! call returns — but at the *batch* axis instead of the column axis, and
//! with no exchange barriers: trajectory blocks share only the read-only
//! weight matrix. The two fan-outs compose: each shard worker's reads
//! dispatch through the same runtime-selected microkernel.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::analog::clamp::Clamp;
use crate::analog::integrator::IvpIntegrator;
use crate::analog::relu::DiodeRelu;
use crate::analog::system::AnalogNeuralOde;
use crate::analog::tia::Tia;
use crate::coordinator::telemetry::Telemetry;
use crate::crossbar::tiling::ShardPlan;
use crate::crossbar::vmm::VmmEngine;
use crate::util::rng::NoiseLane;
use crate::util::tensor::Trajectory;

/// Per-shard serving counters (lock-free; written by shard workers).
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Circuit steps this shard executed.
    pub steps: AtomicU64,
    /// Per-layer device reads this shard issued.
    pub device_reads: AtomicU64,
    /// Wall time this shard worker spent inside rollouts (ns).
    pub busy_ns: AtomicU64,
}

/// Telemetry for one sharded solver: a rollout counter plus one
/// [`ShardCounters`] per shard worker.
#[derive(Debug)]
pub struct ShardTelemetry {
    pub rollouts: AtomicU64,
    pub per_shard: Vec<ShardCounters>,
}

impl ShardTelemetry {
    fn new(n_shards: usize) -> Self {
        Self {
            rollouts: AtomicU64::new(0),
            per_shard: (0..n_shards).map(|_| ShardCounters::default()).collect(),
        }
    }

    /// Point-in-time per-shard snapshot.
    pub fn snapshot(&self) -> Vec<ShardSnapshot> {
        self.per_shard
            .iter()
            .enumerate()
            .map(|(shard, c)| ShardSnapshot {
                shard,
                steps: c.steps.load(Ordering::Relaxed),
                device_reads: c.device_reads.load(Ordering::Relaxed),
                busy_ns: c.busy_ns.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Immutable per-shard counters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub steps: u64,
    pub device_reads: u64,
    pub busy_ns: u64,
}

/// Fan-out policy for sharded rollouts: how many shard workers one
/// trajectory spreads across, whether the groups of one batched dispatch
/// fuse into a single barrier schedule, and (optionally) the coordinator
/// telemetry the workers report into.
#[derive(Debug, Clone, Default)]
pub struct ShardExecutor {
    /// Upper bound on shard workers (the shard count is additionally
    /// clamped to the narrowest layer width).
    pub max_workers: usize,
    /// Co-schedule the sub-batch groups of one dispatch through
    /// [`ShardedAnalogOde::solve_groups_into`] (one thread scope, one
    /// fused barrier sequence) instead of one fan-out per group.
    pub coschedule: bool,
    coord: Option<Arc<Telemetry>>,
}

impl ShardExecutor {
    pub fn new(max_workers: usize) -> Self {
        Self {
            max_workers: max_workers.max(1),
            coschedule: false,
            coord: None,
        }
    }

    pub fn with_coschedule(mut self, on: bool) -> Self {
        self.coschedule = on;
        self
    }
}

/// Co-schedule default for registries built without a [`SystemConfig`]:
/// the `MEMODE_COSCHEDULE` toggle (unset or unparsable keeps it off).
pub fn coschedule_from_env() -> bool {
    crate::config::env_bool("MEMODE_COSCHEDULE").unwrap_or(false)
}

/// Everything a shard worker needs for one rollout, borrowed from the
/// solver for the lifetime of the thread scope.
struct RolloutCtx<'a> {
    batch: usize,
    substeps: usize,
    dt: f64,
    n_points: usize,
    d_state: usize,
    h0s: &'a [f64],
    plans: &'a [ShardPlan],
    layer_cols: &'a [usize],
    /// Exchange buffers: slot 0 is the assembled state `[batch * d]`,
    /// slot l >= 1 the full output of hidden layer l-1.
    exchange: &'a [Mutex<Vec<f64>>],
    barrier: &'a Barrier,
    telemetry: &'a ShardTelemetry,
    /// Initial per-trajectory noise-lane states; every worker copies them
    /// and advances its copies in lockstep (indexed draws).
    lanes: &'a [NoiseLane],
}

/// One shard worker: the tile column-group engines of every layer, the
/// integrator bank behind its state slice, and private scratch.
struct ShardUnit {
    engines: Vec<VmmEngine>,
    tia: Tia,
    relu: DiodeRelu,
    clamp: Clamp,
    /// Integrator templates for this shard's state slice (circuit
    /// parameters copied from the parent solver).
    template: Vec<IvpIntegrator>,
    /// Per-trajectory banks: `batch * width` integrators, b-major.
    bank: Vec<IvpIntegrator>,
    /// Private copies of the rollout's per-trajectory noise lanes.
    lanes: Vec<NoiseLane>,
    state_range: Range<usize>,
    /// Stacked `[prev activation; 1]` rows for the current layer.
    in_buf: Vec<f64>,
    /// This shard's stacked layer output (`batch * shard width`).
    out_buf: Vec<f64>,
    /// Private copies of the full activations: `full[0]` is the state,
    /// `full[l]` the full output of hidden layer l-1.
    full: Vec<Vec<f64>>,
    /// Sampled own-slice rows: `n_points * batch * width`, reused across
    /// rollouts.
    samples: Vec<f64>,
    /// Per-group rollout state for co-scheduled fan-outs (reused across
    /// calls; empty on the single-group path).
    rolls: Vec<GroupRoll>,
}

/// One co-scheduled group's private per-worker state: the same bank /
/// lane-copy / activation / sample set `run_rollout` keeps in the
/// [`ShardUnit`] itself, duplicated per group so a worker can interleave
/// several trajectories' circuit steps inside one barrier schedule.
#[derive(Default)]
struct GroupRoll {
    bank: Vec<IvpIntegrator>,
    lanes: Vec<NoiseLane>,
    full: Vec<Vec<f64>>,
    samples: Vec<f64>,
}

/// Per-group parameters of a co-scheduled fan-out.
struct GroupCtx<'a> {
    batch: usize,
    substeps: usize,
    dt: f64,
    n_points: usize,
    h0s: &'a [f64],
    /// This group's private exchange buffers (slot 0 state, slot l >= 1
    /// the full output of hidden layer l-1).
    exchange: &'a [Mutex<Vec<f64>>],
    lanes: &'a [NoiseLane],
}

/// Shared context of a co-scheduled fan-out: the per-group parameters
/// plus the solver-wide plan/barrier/telemetry the workers share.
struct FusedCtx<'a> {
    d_state: usize,
    plans: &'a [ShardPlan],
    layer_cols: &'a [usize],
    barrier: &'a Barrier,
    telemetry: &'a ShardTelemetry,
    groups: &'a [GroupCtx<'a>],
}

impl ShardUnit {
    fn width(&self) -> usize {
        self.state_range.len()
    }

    /// Append one sample row (every trajectory's own state slice).
    fn push_sample(&mut self, batch: usize) {
        let w = self.width();
        for b in 0..batch {
            for integ in &self.bank[b * w..(b + 1) * w] {
                self.samples.push(integ.v);
            }
        }
    }

    /// The shard worker's whole rollout, barrier-synchronised with its
    /// peers at every exchange point.
    fn run_rollout(&mut self, s: usize, ctx: &RolloutCtx<'_>) {
        let wall = Instant::now();
        let batch = ctx.batch;
        let w = self.width();
        let d = ctx.d_state;
        let n_layers = self.engines.len();
        // Pre-charge a private bank for this shard's state slice.
        self.bank.clear();
        self.bank.reserve(batch * w);
        for b in 0..batch {
            for (i, src) in self.template.iter().enumerate() {
                let mut integ = src.clone();
                integ.stop();
                integ.set_initial(
                    ctx.h0s[b * d + self.state_range.start + i],
                );
                integ.start_integration();
                self.bank.push(integ);
            }
        }
        for (l, buf) in self.full.iter_mut().enumerate() {
            let width = if l == 0 { d } else { ctx.layer_cols[l - 1] };
            buf.resize(batch * width, 0.0);
        }
        self.lanes.clear();
        self.lanes.extend_from_slice(ctx.lanes);
        self.samples.clear();
        self.samples
            .reserve(ctx.n_points.max(1) * batch * w);
        self.push_sample(batch);
        let mut steps: u64 = 0;
        let mut reads: u64 = 0;
        for _ in 1..ctx.n_points {
            for _ in 0..ctx.substeps {
                // Publish own state slice, then read the assembled state.
                {
                    let mut sb =
                        ctx.exchange[0].lock().expect("state exchange");
                    for b in 0..batch {
                        for (i, integ) in
                            self.bank[b * w..(b + 1) * w].iter().enumerate()
                        {
                            sb[b * d + self.state_range.start + i] = integ.v;
                        }
                    }
                }
                ctx.barrier.wait();
                {
                    let sb = ctx.exchange[0].lock().expect("state exchange");
                    self.full[0].copy_from_slice(&sb);
                }
                ctx.barrier.wait();
                for l in 0..n_layers {
                    let rows = self.engines[l].rows();
                    let src_dim = rows - 1;
                    let cols = self.engines[l].cols();
                    self.in_buf.resize(batch * rows, 0.0);
                    for b in 0..batch {
                        let dst =
                            &mut self.in_buf[b * rows..(b + 1) * rows];
                        dst[..src_dim].copy_from_slice(
                            &self.full[l][b * src_dim..(b + 1) * src_dim],
                        );
                        dst[src_dim] = 1.0;
                    }
                    self.out_buf.resize(batch * cols, 0.0);
                    // The column-shard engine draws each trajectory's
                    // noise at full-layer indices and advances the lane
                    // copies by the full-layer draw count — every worker's
                    // copies move in lockstep with the monolithic solver.
                    self.engines[l].vmm_batch_into(
                        &self.in_buf,
                        batch,
                        &mut self.out_buf,
                        &mut self.lanes,
                    );
                    reads += 1;
                    let is_last = l + 1 == n_layers;
                    self.tia.convert_slice(&mut self.out_buf);
                    if !is_last {
                        self.relu.activate_slice(&mut self.out_buf);
                    }
                    self.clamp.apply_slice(&mut self.out_buf);
                    if is_last {
                        // The last layer's columns *are* this shard's state
                        // slice: feed the private bank, no exchange.
                        for (integ, &dv) in
                            self.bank.iter_mut().zip(self.out_buf.iter())
                        {
                            integ.step(dv, ctx.dt);
                        }
                    } else {
                        let rg = ctx.plans[l].range(s);
                        let full_w = ctx.layer_cols[l];
                        {
                            let mut hb = ctx.exchange[l + 1]
                                .lock()
                                .expect("hidden exchange");
                            for b in 0..batch {
                                hb[b * full_w + rg.start
                                    ..b * full_w + rg.end]
                                    .copy_from_slice(
                                        &self.out_buf
                                            [b * cols..(b + 1) * cols],
                                    );
                            }
                        }
                        ctx.barrier.wait();
                        {
                            let hb = ctx.exchange[l + 1]
                                .lock()
                                .expect("hidden exchange");
                            self.full[l + 1].copy_from_slice(&hb);
                        }
                        ctx.barrier.wait();
                    }
                }
                steps += 1;
            }
            self.push_sample(batch);
        }
        for integ in &mut self.bank {
            integ.stop();
        }
        let c = &ctx.telemetry.per_shard[s];
        c.steps.fetch_add(steps, Ordering::Relaxed);
        c.device_reads.fetch_add(reads, Ordering::Relaxed);
        c.busy_ns
            .fetch_add(wall.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// The co-scheduled form of [`ShardUnit::run_rollout`]: every group of
    /// one dispatch advances through the *same* barrier sequence, so one
    /// fused substep costs `2 + 2*(n_layers-1)` barriers no matter how
    /// many groups ride it — each barrier's latency is hidden behind the
    /// other groups' useful work. Per group, the operations touching its
    /// state (bank charge, lane draws, layer order, integrator steps) are
    /// exactly `run_rollout`'s, on private per-group buffers, so the
    /// output is bit-identical to running the groups one at a time. The
    /// active set at fused substep `t` is a pure function of the group
    /// parameters, so every worker executes the same barrier count even
    /// as short groups finish early.
    fn run_groups(&mut self, s: usize, ctx: &FusedCtx<'_>) {
        let wall = Instant::now();
        let w = self.width();
        let d = ctx.d_state;
        let n_layers = self.engines.len();
        let n_groups = ctx.groups.len();
        if self.rolls.len() < n_groups {
            self.rolls.resize_with(n_groups, GroupRoll::default);
        }
        let mut totals: Vec<usize> = Vec::with_capacity(n_groups);
        for (gi, g) in ctx.groups.iter().enumerate() {
            let roll = &mut self.rolls[gi];
            roll.bank.clear();
            roll.bank.reserve(g.batch * w);
            for b in 0..g.batch {
                for (i, src) in self.template.iter().enumerate() {
                    let mut integ = src.clone();
                    integ.stop();
                    integ.set_initial(
                        g.h0s[b * d + self.state_range.start + i],
                    );
                    integ.start_integration();
                    roll.bank.push(integ);
                }
            }
            if roll.full.len() != n_layers {
                roll.full.resize_with(n_layers, Vec::new);
            }
            for (l, buf) in roll.full.iter_mut().enumerate() {
                let width = if l == 0 { d } else { ctx.layer_cols[l - 1] };
                buf.resize(g.batch * width, 0.0);
            }
            roll.lanes.clear();
            roll.lanes.extend_from_slice(g.lanes);
            roll.samples.clear();
            roll.samples.reserve(g.n_points.max(1) * g.batch * w);
            for b in 0..g.batch {
                for integ in &roll.bank[b * w..(b + 1) * w] {
                    roll.samples.push(integ.v);
                }
            }
            totals.push(g.substeps * g.n_points.saturating_sub(1));
        }
        let max_total = totals.iter().copied().max().unwrap_or(0);
        let mut steps: u64 = 0;
        let mut reads: u64 = 0;
        for t in 0..max_total {
            // Publish every active group's state slice, then one barrier
            // pair covers all of them.
            for (gi, g) in ctx.groups.iter().enumerate() {
                if t >= totals[gi] {
                    continue;
                }
                let roll = &self.rolls[gi];
                let mut sb = g.exchange[0].lock().expect("state exchange");
                for b in 0..g.batch {
                    for (i, integ) in
                        roll.bank[b * w..(b + 1) * w].iter().enumerate()
                    {
                        sb[b * d + self.state_range.start + i] = integ.v;
                    }
                }
            }
            ctx.barrier.wait();
            for (gi, g) in ctx.groups.iter().enumerate() {
                if t >= totals[gi] {
                    continue;
                }
                let sb = g.exchange[0].lock().expect("state exchange");
                self.rolls[gi].full[0].copy_from_slice(&sb);
            }
            ctx.barrier.wait();
            for l in 0..n_layers {
                let is_last = l + 1 == n_layers;
                for (gi, g) in ctx.groups.iter().enumerate() {
                    if t >= totals[gi] {
                        continue;
                    }
                    let roll = &mut self.rolls[gi];
                    let rows = self.engines[l].rows();
                    let src_dim = rows - 1;
                    let cols = self.engines[l].cols();
                    self.in_buf.resize(g.batch * rows, 0.0);
                    for b in 0..g.batch {
                        let dst =
                            &mut self.in_buf[b * rows..(b + 1) * rows];
                        dst[..src_dim].copy_from_slice(
                            &roll.full[l]
                                [b * src_dim..(b + 1) * src_dim],
                        );
                        dst[src_dim] = 1.0;
                    }
                    self.out_buf.resize(g.batch * cols, 0.0);
                    self.engines[l].vmm_batch_into(
                        &self.in_buf,
                        g.batch,
                        &mut self.out_buf,
                        &mut roll.lanes,
                    );
                    reads += 1;
                    self.tia.convert_slice(&mut self.out_buf);
                    if !is_last {
                        self.relu.activate_slice(&mut self.out_buf);
                    }
                    self.clamp.apply_slice(&mut self.out_buf);
                    if is_last {
                        for (integ, &dv) in
                            roll.bank.iter_mut().zip(self.out_buf.iter())
                        {
                            integ.step(dv, g.dt);
                        }
                    } else {
                        let rg = ctx.plans[l].range(s);
                        let full_w = ctx.layer_cols[l];
                        let mut hb = g.exchange[l + 1]
                            .lock()
                            .expect("hidden exchange");
                        for b in 0..g.batch {
                            hb[b * full_w + rg.start
                                ..b * full_w + rg.end]
                                .copy_from_slice(
                                    &self.out_buf
                                        [b * cols..(b + 1) * cols],
                                );
                        }
                    }
                }
                if !is_last {
                    ctx.barrier.wait();
                    for (gi, g) in ctx.groups.iter().enumerate() {
                        if t >= totals[gi] {
                            continue;
                        }
                        let hb = g.exchange[l + 1]
                            .lock()
                            .expect("hidden exchange");
                        self.rolls[gi].full[l + 1].copy_from_slice(&hb);
                    }
                    ctx.barrier.wait();
                }
            }
            for (gi, g) in ctx.groups.iter().enumerate() {
                if t >= totals[gi] {
                    continue;
                }
                steps += 1;
                if (t + 1) % g.substeps == 0 {
                    let roll = &mut self.rolls[gi];
                    for b in 0..g.batch {
                        for i in 0..w {
                            roll.samples.push(roll.bank[b * w + i].v);
                        }
                    }
                }
            }
        }
        for roll in self.rolls.iter_mut().take(n_groups) {
            for integ in &mut roll.bank {
                integ.stop();
            }
        }
        let c = &ctx.telemetry.per_shard[s];
        c.steps.fetch_add(steps, Ordering::Relaxed);
        c.device_reads.fetch_add(reads, Ordering::Relaxed);
        c.busy_ns
            .fetch_add(wall.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// A closed-loop analogue solver whose rollouts fan out across parallel
/// shard workers (one scoped thread per tile column-group shard, barrier
/// per exchange point), with results stitched back into one pooled
/// [`Trajectory`]. Built from a deployed [`AnalogNeuralOde`]; with
/// per-trajectory noise lanes its output is bit-identical to that
/// solver's in every noise mode.
pub struct ShardedAnalogOde {
    d_state: usize,
    dt_circuit: f64,
    layer_cols: Vec<usize>,
    plans: Vec<ShardPlan>,
    state_plan: ShardPlan,
    units: Vec<ShardUnit>,
    executor: ShardExecutor,
    telemetry: Arc<ShardTelemetry>,
    /// Exchange buffers shared by the shard workers of one rollout.
    exchange: Vec<Mutex<Vec<f64>>>,
    /// Stitching scratch: one assembled output row.
    row_buf: Vec<f64>,
}

impl ShardedAnalogOde {
    /// Build the fan-out solver from a deployed closed loop. The shard
    /// count is `executor.max_workers` clamped to the narrowest layer
    /// width; rollouts draw noise from caller-supplied per-trajectory
    /// lanes (workers run private copies in lockstep). Only autonomous
    /// systems fan out (`d_drive == 0`).
    pub fn from_ode(ode: &AnalogNeuralOde, executor: ShardExecutor) -> Self {
        assert_eq!(
            ode.d_drive, 0,
            "sharded fan-out supports autonomous twins (d_drive = 0)"
        );
        let mlp = &ode.mlp;
        let n_layers = mlp.n_layers();
        let spec = crate::analog::system::ShardSpec::for_mlp(
            mlp,
            executor.max_workers,
        );
        let plans = spec.layers;
        let state_plan = spec.state;
        let d_state = ode.integrators.len();
        assert_eq!(state_plan.dim(), d_state);
        let n_shards = state_plan.n_shards();
        let layer_cols: Vec<usize> =
            (0..n_layers).map(|l| mlp.layer_cols(l)).collect();
        let units = (0..n_shards)
            .map(|s| {
                let engines: Vec<VmmEngine> = (0..n_layers)
                    .map(|l| {
                        let r = plans[l].range(s);
                        mlp.engine(l).column_shard(r.start, r.end)
                    })
                    .collect();
                let (tia, relu, clamp) = mlp.peripherals();
                let rg = state_plan.range(s);
                let template = ode.integrators[rg.clone()].to_vec();
                ShardUnit {
                    engines,
                    tia,
                    relu,
                    clamp,
                    template,
                    bank: Vec::new(),
                    lanes: Vec::new(),
                    state_range: rg,
                    in_buf: Vec::new(),
                    out_buf: Vec::new(),
                    full: vec![Vec::new(); n_layers],
                    samples: Vec::new(),
                    rolls: Vec::new(),
                }
            })
            .collect();
        let exchange =
            (0..n_layers).map(|_| Mutex::new(Vec::new())).collect();
        Self {
            d_state,
            dt_circuit: ode.dt_circuit,
            layer_cols,
            plans,
            state_plan,
            units,
            executor,
            telemetry: Arc::new(ShardTelemetry::new(n_shards)),
            exchange,
            row_buf: Vec::new(),
        }
    }

    pub fn d_state(&self) -> usize {
        self.d_state
    }

    /// Shard workers one rollout fans out across.
    pub fn n_shards(&self) -> usize {
        self.units.len()
    }

    /// The state partition.
    pub fn state_plan(&self) -> &ShardPlan {
        &self.state_plan
    }

    /// Per-shard serving counters.
    pub fn telemetry(&self) -> &ShardTelemetry {
        &self.telemetry
    }

    /// Report rollout counters into the coordinator's serving telemetry.
    pub fn attach_coordinator_telemetry(&mut self, t: Arc<Telemetry>) {
        self.executor.coord = Some(t);
    }

    /// Whether batched dispatches should fuse their sub-batch groups into
    /// one co-scheduled fan-out ([`ShardedAnalogOde::solve_groups_into`]).
    pub fn coschedule(&self) -> bool {
        self.executor.coschedule
    }

    pub fn set_coschedule(&mut self, on: bool) {
        self.executor.coschedule = on;
    }

    /// Batched sharded rollout: `batch` trajectories in lockstep from the
    /// flat `[batch * d]` initial states, every circuit step executed by
    /// the shard workers in parallel (barrier per exchange point), sampled
    /// every `dt_out` into `out` (reset to row width `batch * d`; the
    /// shards' sample slices are stitched into full rows). `lanes` carries
    /// one noise lane per trajectory; every worker advances private copies
    /// in lockstep, and the caller's lanes are left at the same cursor a
    /// monolithic rollout would leave them.
    pub fn solve_batch_into(
        &mut self,
        h0s: &[f64],
        batch: usize,
        dt_out: f64,
        n_points: usize,
        lanes: &mut [NoiseLane],
        out: &mut Trajectory,
    ) {
        let d = self.d_state;
        let n_shards = self.units.len();
        assert_eq!(
            h0s.len(),
            batch * d,
            "sharded solve [{} shards]: h0s length {} != batch {} * state \
             dim {}",
            n_shards,
            h0s.len(),
            batch,
            d
        );
        assert_eq!(
            lanes.len(),
            batch,
            "sharded solve: one noise lane per trajectory"
        );
        let substeps =
            ((dt_out / self.dt_circuit).round() as usize).max(1);
        let dt = dt_out / substeps as f64;
        for (l, m) in self.exchange.iter_mut().enumerate() {
            let width = if l == 0 { d } else { self.layer_cols[l - 1] };
            m.get_mut().expect("exchange").resize(batch * width, 0.0);
        }
        let barrier = Barrier::new(n_shards);
        let ctx = RolloutCtx {
            batch,
            substeps,
            dt,
            n_points,
            d_state: d,
            h0s,
            plans: &self.plans,
            layer_cols: &self.layer_cols,
            exchange: &self.exchange,
            barrier: &barrier,
            telemetry: &self.telemetry,
            lanes: &*lanes,
        };
        // Fan out: one scoped worker per shard, joined before stitching.
        std::thread::scope(|scope| {
            for (s, unit) in self.units.iter_mut().enumerate() {
                let ctx = &ctx;
                scope.spawn(move || unit.run_rollout(s, ctx));
            }
        });
        // All workers advanced their lane copies identically; hand the
        // final cursors back so warm callers stay in sync with the
        // monolithic path.
        lanes.copy_from_slice(&self.units[0].lanes[..batch]);
        self.telemetry.rollouts.fetch_add(1, Ordering::Relaxed);
        if let Some(coord) = &self.executor.coord {
            coord.shard_rollouts.fetch_add(1, Ordering::Relaxed);
            let steps = (n_shards * substeps * n_points.saturating_sub(1))
                as u64;
            coord.shard_steps.fetch_add(steps, Ordering::Relaxed);
        }
        // Stitch the shards' sample slices into full pooled rows.
        out.reset(batch * d);
        out.reserve_rows(n_points.max(1));
        self.row_buf.resize(batch * d, 0.0);
        for p in 0..n_points.max(1) {
            for unit in &self.units {
                let w = unit.width();
                let row =
                    &unit.samples[p * batch * w..(p + 1) * batch * w];
                for b in 0..batch {
                    self.row_buf[b * d + unit.state_range.start
                        ..b * d + unit.state_range.end]
                        .copy_from_slice(&row[b * w..(b + 1) * w]);
                }
            }
            out.push_row(&self.row_buf);
        }
    }

    /// Single-trajectory sharded rollout (a batch of one).
    pub fn solve_into(
        &mut self,
        h0: &[f64],
        dt_out: f64,
        n_points: usize,
        lane: &mut NoiseLane,
        out: &mut Trajectory,
    ) {
        self.solve_batch_into(
            h0,
            1,
            dt_out,
            n_points,
            std::slice::from_mut(lane),
            out,
        );
    }

    /// Co-scheduled fan-out: several independent batched rollouts
    /// ("groups" — the compatible sub-batches of one dispatch) share the
    /// shard workers of a *single* thread scope and a *single* fused
    /// barrier schedule. Every fused circuit substep costs the same
    /// `2 + 2*(n_layers-1)` barriers one group alone would pay, so each
    /// barrier's synchronisation latency is hidden behind the other
    /// groups' tile reads. Groups may differ in batch width, `n_points`
    /// and `dt_out` (short groups drop out of the schedule
    /// deterministically); each group's output and final lane cursors are
    /// bit-identical to a sequence of [`ShardedAnalogOde::solve_batch_into`]
    /// calls, because per group the fused schedule performs exactly the
    /// same operations in the same order on private per-group state.
    pub fn solve_groups_into(&mut self, groups: &mut [ShardGroup<'_>]) {
        if groups.is_empty() {
            return;
        }
        if groups.len() == 1 {
            let g = &mut groups[0];
            let (h0s, batch, dt_out, n_points) =
                (g.h0s, g.batch, g.dt_out, g.n_points);
            self.solve_batch_into(
                h0s, batch, dt_out, n_points, g.lanes, g.out,
            );
            return;
        }
        let d = self.d_state;
        let n_shards = self.units.len();
        let n_layers = self.layer_cols.len();
        // Per-group private exchange buffers (the co-scheduled path
        // allocates per call, like the rest of the fan-out form).
        let mut exchanges: Vec<Vec<Mutex<Vec<f64>>>> =
            Vec::with_capacity(groups.len());
        let mut substeps: Vec<usize> = Vec::with_capacity(groups.len());
        for g in groups.iter() {
            assert_eq!(
                g.h0s.len(),
                g.batch * d,
                "co-scheduled solve: h0s length {} != batch {} * state \
                 dim {}",
                g.h0s.len(),
                g.batch,
                d
            );
            assert_eq!(
                g.lanes.len(),
                g.batch,
                "co-scheduled solve: one noise lane per trajectory"
            );
            exchanges.push(
                (0..n_layers)
                    .map(|l| {
                        let width = if l == 0 {
                            d
                        } else {
                            self.layer_cols[l - 1]
                        };
                        Mutex::new(vec![0.0; g.batch * width])
                    })
                    .collect(),
            );
            substeps.push(
                ((g.dt_out / self.dt_circuit).round() as usize).max(1),
            );
        }
        let gctxs: Vec<GroupCtx<'_>> = groups
            .iter()
            .zip(&exchanges)
            .zip(&substeps)
            .map(|((g, ex), &ss)| GroupCtx {
                batch: g.batch,
                substeps: ss,
                dt: g.dt_out / ss as f64,
                n_points: g.n_points,
                h0s: g.h0s,
                exchange: ex,
                lanes: &*g.lanes,
            })
            .collect();
        let barrier = Barrier::new(n_shards);
        let fctx = FusedCtx {
            d_state: d,
            plans: &self.plans,
            layer_cols: &self.layer_cols,
            barrier: &barrier,
            telemetry: &self.telemetry,
            groups: &gctxs,
        };
        std::thread::scope(|scope| {
            for (s, unit) in self.units.iter_mut().enumerate() {
                let fctx = &fctx;
                scope.spawn(move || unit.run_groups(s, fctx));
            }
        });
        drop(fctx);
        drop(gctxs);
        self.telemetry
            .rollouts
            .fetch_add(groups.len() as u64, Ordering::Relaxed);
        if let Some(coord) = &self.executor.coord {
            coord
                .shard_rollouts
                .fetch_add(groups.len() as u64, Ordering::Relaxed);
            for (g, &ss) in groups.iter().zip(&substeps) {
                let steps = (n_shards
                    * ss
                    * g.n_points.saturating_sub(1))
                    as u64;
                coord.shard_steps.fetch_add(steps, Ordering::Relaxed);
            }
        }
        // Hand back lane cursors and stitch each group's pooled rows.
        for (gi, g) in groups.iter_mut().enumerate() {
            g.lanes.copy_from_slice(
                &self.units[0].rolls[gi].lanes[..g.batch],
            );
            g.out.reset(g.batch * d);
            g.out.reserve_rows(g.n_points.max(1));
            self.row_buf.resize(g.batch * d, 0.0);
            for p in 0..g.n_points.max(1) {
                for unit in &self.units {
                    let w = unit.width();
                    let row = &unit.rolls[gi].samples
                        [p * g.batch * w..(p + 1) * g.batch * w];
                    for b in 0..g.batch {
                        self.row_buf[b * d + unit.state_range.start
                            ..b * d + unit.state_range.end]
                            .copy_from_slice(&row[b * w..(b + 1) * w]);
                    }
                }
                g.out.push_row(&self.row_buf);
            }
        }
    }
}

/// One group of a co-scheduled fan-out
/// ([`ShardedAnalogOde::solve_groups_into`]): the argument set of one
/// [`ShardedAnalogOde::solve_batch_into`] call.
pub struct ShardGroup<'a> {
    pub h0s: &'a [f64],
    pub batch: usize,
    pub dt_out: f64,
    pub n_points: usize,
    pub lanes: &'a mut [NoiseLane],
    pub out: &'a mut Trajectory,
}

impl std::fmt::Debug for ShardedAnalogOde {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedAnalogOde")
            .field("d_state", &self.d_state)
            .field("n_shards", &self.units.len())
            .field("dt_circuit", &self.dt_circuit)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::system::{AnalogMlp, AnalogNoise, LayerWeights};
    use crate::device::taox::DeviceConfig;

    /// f(h) = -h element-wise for dimension d (the shared exact-ReLU
    /// decay fixture).
    fn wide_decay_layers(d: usize) -> Vec<LayerWeights> {
        crate::models::loader::decay_mlp_weights(d)
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect()
    }

    fn deployed_pair(d: usize, n_shards: usize) -> (AnalogNeuralOde, ShardedAnalogOde) {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mlp = AnalogMlp::deploy(
            &wide_decay_layers(d),
            &cfg,
            AnalogNoise::off(),
            11,
        );
        let ode = AnalogNeuralOde::new(mlp, d, 0.01);
        let sharded =
            ShardedAnalogOde::from_ode(&ode, ShardExecutor::new(n_shards));
        (ode, sharded)
    }

    #[test]
    fn fanout_rollout_bit_identical_to_monolithic() {
        let d = 34;
        let (mut mono, mut sharded) = deployed_pair(d, 2);
        assert_eq!(sharded.n_shards(), 2);
        let h0: Vec<f64> =
            (0..d).map(|i| ((i as f64) * 0.29).sin() * 0.7).collect();
        let want = mono.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 6);
        let mut got = Trajectory::new(d);
        let mut lane = NoiseLane::from_seed(1);
        sharded.solve_into(&h0, 0.1, 6, &mut lane, &mut got);
        assert_eq!(got, want, "fan-out rollout diverged from monolithic");
    }

    #[test]
    fn fanout_batched_rollout_bit_identical_to_monolithic() {
        let d = 34;
        let (mut mono, mut sharded) = deployed_pair(d, 2);
        let batch = 3;
        let h0s: Vec<f64> = (0..batch * d)
            .map(|k| ((k as f64) * 0.17).cos() * 0.5)
            .collect();
        let want =
            mono.solve_batch(&h0s, batch, &mut |_b, _t, _x| {}, 0.1, 5);
        let mut got = Trajectory::new(batch * d);
        let mut lanes: Vec<NoiseLane> =
            (0..batch as u64).map(NoiseLane::from_seed).collect();
        sharded.solve_batch_into(&h0s, batch, 0.1, 5, &mut lanes, &mut got);
        assert_eq!(got, want, "fan-out batched rollout diverged");
    }

    #[test]
    fn noisy_fanout_rollout_bit_identical_to_monolithic() {
        // The noise-lane upgrade: the parallel fan-out consumes the exact
        // draws the monolithic solver does, so even *noisy* rollouts are
        // bit-identical — and the caller's lane lands on the same cursor.
        let d = 34;
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mlp = AnalogMlp::deploy(&wide_decay_layers(d), &cfg, noise, 13);
        let mut mono = AnalogNeuralOde::new(mlp, d, 0.01);
        let mut sharded =
            ShardedAnalogOde::from_ode(&mono, ShardExecutor::new(2));
        let h0: Vec<f64> =
            (0..d).map(|i| ((i as f64) * 0.19).sin() * 0.5).collect();
        let mut want = Trajectory::new(d);
        let mut mono_lane = NoiseLane::from_seed(77);
        mono.solve_into(
            &h0,
            &mut |_t, _x: &mut [f64]| {},
            0.1,
            4,
            &mut mono_lane,
            &mut want,
        );
        let mut got = Trajectory::new(d);
        let mut lane = NoiseLane::from_seed(77);
        sharded.solve_into(&h0, 0.1, 4, &mut lane, &mut got);
        assert_eq!(got, want, "noisy fan-out diverged from monolithic");
        assert_eq!(lane, mono_lane, "fan-out lane cursor diverged");
    }

    #[test]
    fn warm_fanout_reuses_buffers_and_stays_exact() {
        let d = 34;
        let (mut mono, mut sharded) = deployed_pair(d, 2);
        let h0: Vec<f64> = (0..d).map(|i| (i as f64) * 0.01 - 0.1).collect();
        let mut out = Trajectory::new(d);
        // Warm with a larger problem, then solve the real one.
        let big: Vec<f64> = (0..3 * d).map(|k| (k as f64) * 0.003).collect();
        let mut lanes: Vec<NoiseLane> =
            (0..3u64).map(NoiseLane::from_seed).collect();
        sharded.solve_batch_into(&big, 3, 0.1, 7, &mut lanes, &mut out);
        let mut lane = NoiseLane::from_seed(9);
        sharded.solve_into(&h0, 0.1, 4, &mut lane, &mut out);
        let want = mono.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 4);
        assert_eq!(out, want, "warm fan-out scratch leaked state");
    }

    #[test]
    fn per_shard_telemetry_records_steps_and_reads() {
        let d = 34;
        let (_, mut sharded) = deployed_pair(d, 2);
        let h0 = vec![0.1; d];
        let mut out = Trajectory::new(d);
        let mut lane = NoiseLane::from_seed(3);
        sharded.solve_into(&h0, 0.1, 3, &mut lane, &mut out);
        let snap = sharded.telemetry().snapshot();
        assert_eq!(snap.len(), 2);
        for s in &snap {
            assert!(s.steps > 0, "shard {} idle", s.shard);
            assert!(s.device_reads > 0, "shard {} read nothing", s.shard);
        }
        assert_eq!(
            sharded.telemetry().rollouts.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn coordinator_telemetry_receives_shard_counters() {
        let d = 34;
        let (_, mut sharded) = deployed_pair(d, 2);
        let tel = Arc::new(Telemetry::new());
        sharded.attach_coordinator_telemetry(Arc::clone(&tel));
        let mut out = Trajectory::new(d);
        let h0 = vec![0.05; d];
        let mut lane = NoiseLane::from_seed(4);
        sharded.solve_into(&h0, 0.1, 3, &mut lane, &mut out);
        let snap = tel.snapshot();
        assert_eq!(snap.shard_rollouts, 1);
        assert!(snap.shard_steps > 0);
    }

    #[test]
    fn coscheduled_groups_bit_identical_to_sequential_rollouts() {
        // Two ragged groups (different batch widths, lengths) fused into
        // one barrier schedule must reproduce back-to-back
        // solve_batch_into calls byte for byte, lanes included.
        let d = 34;
        let (_, mut seq) = deployed_pair(d, 2);
        let (_, mut fused) = deployed_pair(d, 2);
        let h0a: Vec<f64> = (0..2 * d)
            .map(|k| ((k as f64) * 0.11).sin() * 0.4)
            .collect();
        let h0b: Vec<f64> = (0..3 * d)
            .map(|k| ((k as f64) * 0.07).cos() * 0.6)
            .collect();
        let mut want_a = Trajectory::new(2 * d);
        let mut want_b = Trajectory::new(3 * d);
        let mut seq_lanes_a: Vec<NoiseLane> =
            (0..2u64).map(|k| NoiseLane::from_seed(100 + k)).collect();
        let mut seq_lanes_b: Vec<NoiseLane> =
            (0..3u64).map(|k| NoiseLane::from_seed(200 + k)).collect();
        seq.solve_batch_into(&h0a, 2, 0.1, 5, &mut seq_lanes_a, &mut want_a);
        seq.solve_batch_into(&h0b, 3, 0.1, 7, &mut seq_lanes_b, &mut want_b);
        let mut got_a = Trajectory::new(2 * d);
        let mut got_b = Trajectory::new(3 * d);
        let mut lanes_a: Vec<NoiseLane> =
            (0..2u64).map(|k| NoiseLane::from_seed(100 + k)).collect();
        let mut lanes_b: Vec<NoiseLane> =
            (0..3u64).map(|k| NoiseLane::from_seed(200 + k)).collect();
        let mut groups = [
            ShardGroup {
                h0s: &h0a,
                batch: 2,
                dt_out: 0.1,
                n_points: 5,
                lanes: &mut lanes_a,
                out: &mut got_a,
            },
            ShardGroup {
                h0s: &h0b,
                batch: 3,
                dt_out: 0.1,
                n_points: 7,
                lanes: &mut lanes_b,
                out: &mut got_b,
            },
        ];
        fused.solve_groups_into(&mut groups);
        assert_eq!(got_a, want_a, "co-scheduled group A diverged");
        assert_eq!(got_b, want_b, "co-scheduled group B diverged");
        assert_eq!(lanes_a, seq_lanes_a, "group A lane cursors diverged");
        assert_eq!(lanes_b, seq_lanes_b, "group B lane cursors diverged");
        assert_eq!(
            fused.telemetry().rollouts.load(Ordering::Relaxed),
            2,
            "each group counts as one rollout"
        );
    }

    #[test]
    fn noisy_coscheduled_groups_bit_identical_to_sequential() {
        // With read noise on, the fused schedule must consume exactly the
        // per-group draws the sequential rollouts do.
        let d = 34;
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let build = || {
            let mlp =
                AnalogMlp::deploy(&wide_decay_layers(d), &cfg, noise, 13);
            let ode = AnalogNeuralOde::new(mlp, d, 0.01);
            ShardedAnalogOde::from_ode(&ode, ShardExecutor::new(2))
        };
        let mut seq = build();
        let mut fused = build();
        let h0a: Vec<f64> =
            (0..d).map(|i| ((i as f64) * 0.19).sin() * 0.5).collect();
        let h0b: Vec<f64> = (0..2 * d)
            .map(|k| ((k as f64) * 0.23).cos() * 0.3)
            .collect();
        let mut want_a = Trajectory::new(d);
        let mut want_b = Trajectory::new(2 * d);
        let mut seq_lane_a = vec![NoiseLane::from_seed(77)];
        let mut seq_lanes_b: Vec<NoiseLane> =
            (0..2u64).map(|k| NoiseLane::from_seed(300 + k)).collect();
        seq.solve_batch_into(&h0a, 1, 0.1, 4, &mut seq_lane_a, &mut want_a);
        seq.solve_batch_into(&h0b, 2, 0.1, 6, &mut seq_lanes_b, &mut want_b);
        let mut got_a = Trajectory::new(d);
        let mut got_b = Trajectory::new(2 * d);
        let mut lane_a = vec![NoiseLane::from_seed(77)];
        let mut lanes_b: Vec<NoiseLane> =
            (0..2u64).map(|k| NoiseLane::from_seed(300 + k)).collect();
        let mut groups = [
            ShardGroup {
                h0s: &h0a,
                batch: 1,
                dt_out: 0.1,
                n_points: 4,
                lanes: &mut lane_a,
                out: &mut got_a,
            },
            ShardGroup {
                h0s: &h0b,
                batch: 2,
                dt_out: 0.1,
                n_points: 6,
                lanes: &mut lanes_b,
                out: &mut got_b,
            },
        ];
        fused.solve_groups_into(&mut groups);
        assert_eq!(got_a, want_a, "noisy co-scheduled group A diverged");
        assert_eq!(got_b, want_b, "noisy co-scheduled group B diverged");
        assert_eq!(lane_a, seq_lane_a, "group A lane cursor diverged");
        assert_eq!(lanes_b, seq_lanes_b, "group B lane cursors diverged");
    }

    #[test]
    fn single_group_coschedule_delegates_to_batched_path() {
        let d = 34;
        let (_, mut seq) = deployed_pair(d, 2);
        let (_, mut fused) = deployed_pair(d, 2);
        let h0: Vec<f64> =
            (0..d).map(|i| (i as f64) * 0.02 - 0.3).collect();
        let mut want = Trajectory::new(d);
        let mut seq_lane = vec![NoiseLane::from_seed(5)];
        seq.solve_batch_into(&h0, 1, 0.1, 5, &mut seq_lane, &mut want);
        let mut got = Trajectory::new(d);
        let mut lane = vec![NoiseLane::from_seed(5)];
        let mut groups = [ShardGroup {
            h0s: &h0,
            batch: 1,
            dt_out: 0.1,
            n_points: 5,
            lanes: &mut lane,
            out: &mut got,
        }];
        fused.solve_groups_into(&mut groups);
        assert_eq!(got, want);
        assert_eq!(lane, seq_lane);
    }

    #[test]
    fn shard_count_clamps_to_executor_and_layers() {
        let d = 34;
        let (_, sharded) = deployed_pair(d, 64);
        // 2d = 68 columns -> 3 tiles; d = 34 -> 2 tiles: narrowest layer
        // allows 2 tile-group shards... but element splits allow up to the
        // width; the executor asked for 64, clamped by ShardPlan::split to
        // min(64, 34) = 34 element shards on the output layer and 64 on the
        // hidden one -> uniform count is 34.
        assert_eq!(sharded.n_shards(), 34);
        let (_, sharded) = deployed_pair(d, 1);
        assert_eq!(sharded.n_shards(), 1);
    }
}
