//! The HP-memristor digital twin (Fig. 3).
//!
//! State: the normalised doped-region boundary h = w/D (dim 1). Driven by a
//! voltage stimulus. Backends: analogue solver, Rust RK4, recurrent-ResNet
//! baseline, or the AOT PJRT artifact.
//!
//! Since the generic-core refactor this type is thin configuration over
//! [`DynamicsTwin`]: every constructor builds a [`TwinSpec`] (scalar-driven,
//! dim 1, `hp::H0` initial condition) plus a [`CoreBackend`], and all
//! request execution — batching, stimulus staging, seed stamping, ensemble
//! expansion, pooled responses — happens on the shared core path that
//! `twin/core.rs` enforces the invariants on.

use anyhow::Result;

use crate::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use crate::device::taox::DeviceConfig;
use crate::models::loader::MlpWeights;
use crate::models::mlp::Mlp;
use crate::models::resnet::RecurrentResNet;
use crate::twin::core::{
    CoreBackend, DigitalModel, DynamicsTwin, StimulusKind, TwinSpec,
};
use crate::twin::{RolloutFn, Twin, TwinRequest, TwinResponse};
use crate::workload::stimuli::Waveform;

/// Default circuit substeps per output sample for the analogue backend.
pub const ANALOG_SUBSTEPS: usize = 20;
/// Default RK4 substeps per output sample for the digital backend.
pub const DIGITAL_SUBSTEPS: usize = 1;

/// Auto-seed root for backends built without an explicit seed (digital,
/// resnet, pjrt — the seed is still resolved and echoed for replay).
const HP_AUTO_ROOT: u64 = 0x4870_5eed_0000_0001;

/// The HP-memristor twin: configuration of the generic [`DynamicsTwin`]
/// core.
pub struct HpTwin {
    core: DynamicsTwin,
}

impl HpTwin {
    fn spec(dt: f64) -> TwinSpec {
        TwinSpec {
            name: "hp",
            field_label: "hp/digital",
            dim: 1,
            dt,
            default_h0: vec![crate::device::hp::H0],
            stimulus: StimulusKind::DrivenScalar,
            digital_substeps: DIGITAL_SUBSTEPS,
        }
    }

    fn assemble(backend: CoreBackend, dt: f64, lane_root: u64) -> Self {
        Self {
            core: DynamicsTwin::new(Self::spec(dt), backend, lane_root),
        }
    }

    /// Build the analogue-backend twin from trained weights.
    pub fn analog(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let mlp = AnalogMlp::deploy(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let ode =
            AnalogNeuralOde::new(mlp, 1, dt / ANALOG_SUBSTEPS as f64);
        Self::assemble(CoreBackend::Analog(Box::new(ode)), dt, seed)
    }

    /// Analogue-backend twin on *mortal* hardware: deployed via
    /// [`AnalogMlp::deploy_aging`], so the crossbars keep their physical
    /// state and expose the virtual-clock lifetime API
    /// ([`HpTwin::advance_age`], [`HpTwin::recalibrate`], …). At age 0
    /// this twin is bit-identical to [`HpTwin::analog`] under the same
    /// seed and substeps.
    pub fn analog_aging(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
        substeps: usize,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let mlp = AnalogMlp::deploy_aging(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let substeps = substeps.max(1);
        let ode = AnalogNeuralOde::new(mlp, 1, dt / substeps as f64);
        Self::assemble(CoreBackend::Analog(Box::new(ode)), dt, seed)
    }

    /// Build the digital (Rust RK4) twin.
    pub fn digital(weights: &MlpWeights) -> Self {
        Self::assemble(
            CoreBackend::Digital(DigitalModel::Mlp(Mlp::from_weights(
                weights,
            ))),
            weights.dt,
            HP_AUTO_ROOT,
        )
    }

    /// Build the recurrent-ResNet baseline twin.
    pub fn resnet(weights: &MlpWeights) -> Self {
        Self::assemble(
            CoreBackend::Resnet(RecurrentResNet::new(Mlp::from_weights(
                weights,
            ))),
            weights.dt,
            HP_AUTO_ROOT,
        )
    }

    /// Build the PJRT-artifact twin.
    pub fn pjrt(rollout: RolloutFn, dt: f64) -> Self {
        Self::assemble(CoreBackend::Pjrt(rollout), dt, HP_AUTO_ROOT)
    }

    /// Unwrap into the generic core (health monitoring composes twins at
    /// the core layer).
    pub(crate) fn into_core(self) -> DynamicsTwin {
        self.core
    }

    /// Whether this twin runs on mortal (aging) analogue hardware.
    pub fn is_aging(&self) -> bool {
        self.core.is_aging()
    }

    /// Advance the hardware's virtual clock by `dt_s` seconds. Panics on
    /// a non-aging twin.
    pub fn advance_age(&mut self, dt_s: f64) {
        self.core.advance_age(dt_s);
    }

    /// Reprogram every array back to its target weights; returns the
    /// write-verify pulse count.
    pub fn recalibrate(&mut self) -> u64 {
        self.core.recalibrate()
    }

    /// Virtual device age (s); 0 for immortal twins.
    pub fn age_s(&self) -> f64 {
        self.core.age_s()
    }

    /// Healthy-cell fraction across every deployed array (1.0 if
    /// immortal).
    pub fn array_health(&self) -> f64 {
        self.core.array_health()
    }

    /// Lifetime write-verify pulses spent on recalibration.
    pub fn lifetime_pulses(&self) -> u64 {
        self.core.lifetime_pulses()
    }

    /// Completed recalibration count.
    pub fn recalibrations(&self) -> u64 {
        self.core.recalibrations()
    }

    /// Mark a random `fraction` of cells stuck. Panics on a non-aging
    /// twin.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        self.core.inject_stuck_faults(fraction);
    }

    /// Return a response's trajectory buffers to the twin's pool
    /// (ensemble responses hand back every stats trajectory plus the
    /// emptied container shell).
    ///
    /// Optional: callers that hand responses back make the next
    /// `run_batch` draw its output trajectories from the pool instead of
    /// the allocator — the zero-allocation steady state the allocation
    /// test (`rust/tests/alloc.rs`) pins down.
    pub fn recycle(&mut self, resp: TwinResponse) {
        self.core.recycle(resp);
    }

    /// Simulate under a stimulus; returns the scalar state trajectory.
    /// Noise draws come from the next auto-derived lane; use
    /// [`Twin::run`] with a seeded request for replayable rollouts.
    pub fn simulate(
        &mut self,
        wave: &Waveform,
        h0: f64,
        n_points: usize,
    ) -> Result<Vec<f64>> {
        self.core
            .simulate(Some(*wave), &[h0], n_points)
            .map(|t| t.into_data())
    }
}

impl Twin for HpTwin {
    fn name(&self) -> &str {
        self.core.name()
    }

    fn state_dim(&self) -> usize {
        self.core.state_dim()
    }

    fn dt(&self) -> f64 {
        self.core.dt()
    }

    fn default_h0(&self) -> Vec<f64> {
        self.core.default_h0()
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        self.core.run(req)
    }

    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        self.core.run_batch(reqs)
    }

    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        self.core.run_batch_into(reqs, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::hp;
    use crate::metrics::mre::mre;
    use crate::util::tensor::Mat;

    /// Trained-ish weights: use the *true* field via a fine ReLU net is
    /// overkill for unit tests — instead check plumbing with a hand-made
    /// linear field f([v; h]) = 2v - h (exact via paired ReLUs).
    fn toy_weights() -> MlpWeights {
        let w1 = Mat::from_vec(
            2,
            4,
            vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
        );
        let b1 = vec![0.0; 4];
        let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
        let b2 = vec![0.0];
        MlpWeights {
            layers: vec![(w1, b1), (w2, b2)],
            dt: 1e-3,
            kind: "node".into(),
            task: "hp".into(),
        }
    }

    #[test]
    fn digital_twin_solves_linear_driven_ode() {
        let mut twin = HpTwin::digital(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, 0.5, 100).unwrap();
        assert_eq!(h.len(), 100);
        assert_eq!(h[0], 0.5);
        assert!(h.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn analog_and_digital_agree_on_toy_field() {
        let w = toy_weights();
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut ana = HpTwin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut dig = HpTwin::digital(&w);
        let wave = Waveform::sine(1.0, 4.0);
        let ha = ana.simulate(&wave, 0.2, 200).unwrap();
        let hd = dig.simulate(&wave, 0.2, 200).unwrap();
        let err = mre(&ha, &hd);
        assert!(err < 0.05, "analog vs digital MRE {err}");
    }

    #[test]
    fn aging_twin_matches_plain_at_age_zero() {
        let w = toy_weights();
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut plain = HpTwin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut aging = HpTwin::analog_aging(
            &w,
            &cfg,
            AnalogNoise::off(),
            1,
            ANALOG_SUBSTEPS,
        );
        assert!(aging.is_aging() && !plain.is_aging());
        let wave = Waveform::sine(1.0, 4.0);
        let fresh = aging.simulate(&wave, 0.3, 20).unwrap();
        assert_eq!(
            fresh,
            plain.simulate(&wave, 0.3, 20).unwrap(),
            "aging deployment diverged from plain at age 0"
        );
        aging.advance_age(1e7);
        assert_eq!(aging.age_s(), 1e7);
        let aged = aging.simulate(&wave, 0.3, 20).unwrap();
        let dev = |a: &[f64], b: &[f64]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
        };
        assert!(dev(&aged, &fresh) > 0.0, "aging left the rollout intact");
        let pulses = aging.recalibrate();
        assert!(pulses > 0);
        assert_eq!(aging.recalibrations(), 1);
        let recal = aging.simulate(&wave, 0.3, 20).unwrap();
        assert!(
            dev(&recal, &fresh) < dev(&aged, &fresh),
            "recalibration did not move the rollout back"
        );
    }

    #[test]
    fn twin_trait_requires_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::autonomous(vec![], 10);
        assert!(twin.run(&req).is_err());
    }

    #[test]
    fn twin_trait_roundtrip() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::driven(
            vec![0.3],
            50,
            Waveform::triangular(1.0, 4.0),
        );
        let resp = twin.run(&req).unwrap();
        assert_eq!(resp.trajectory.len(), 50);
        assert_eq!(resp.trajectory.dim(), 1);
        assert_eq!(resp.backend, "digital-rk4");
        assert_eq!(resp.trajectory.row(0), [0.3]);
    }

    #[test]
    fn resnet_backend_rolls_out() {
        let mut twin = HpTwin::resnet(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, hp::H0, 20).unwrap();
        assert_eq!(h.len(), 20);
    }

    fn mixed_requests() -> Vec<TwinRequest> {
        vec![
            TwinRequest::driven(vec![0.3], 40, Waveform::sine(1.0, 4.0)),
            TwinRequest::driven(
                vec![0.5],
                25,
                Waveform::triangular(1.0, 4.0),
            ),
            TwinRequest::driven(
                vec![0.2],
                40,
                Waveform::rectangular(1.0, 4.0),
            ),
            TwinRequest::driven(vec![], 40, Waveform::modulated(1.0, 4.0, 1.0)),
        ]
    }

    fn assert_batch_matches_serial(twin: &mut HpTwin) {
        let reqs = mixed_requests();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
            assert_eq!(b.backend, s.backend);
        }
        // A second pass on the now-warm scratch must agree too (pooled
        // buffers never leak stale samples).
        for (resp, s) in twin.run_batch(&reqs).into_iter().zip(&serial) {
            let resp = resp.unwrap();
            assert_eq!(resp.trajectory, s.trajectory);
            twin.recycle(resp);
        }
        let third = twin.run_batch(&reqs);
        for (b, s) in third.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap().trajectory, s.trajectory);
        }
    }

    #[test]
    fn digital_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::digital(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn analog_run_batch_bit_identical_to_serial_noise_free() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin =
            HpTwin::analog(&toy_weights(), &cfg, AnalogNoise::off(), 3);
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn resnet_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::resnet(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn seeded_noisy_run_replays_and_matches_batched() {
        // With read noise ON, a pinned seed makes the rollout replayable
        // and batch-position independent; the response echoes the seed.
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mut twin = HpTwin::analog(&toy_weights(), &cfg, noise, 3);
        let reqs: Vec<TwinRequest> = (0..3)
            .map(|k| {
                TwinRequest::driven(
                    vec![0.2 + 0.1 * k as f64],
                    10,
                    Waveform::sine(1.0, 4.0),
                )
                .with_seed(500 + k as u64)
            })
            .collect();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        for (r, s) in reqs.iter().zip(&serial) {
            assert_eq!(s.seed, r.seed.unwrap(), "seed not echoed");
            // Replay on the same twin: bit-identical.
            let again = twin.run(r).unwrap();
            assert_eq!(again.trajectory, s.trajectory, "replay diverged");
        }
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(
                b.trajectory, s.trajectory,
                "noisy request {k}: batched != serial"
            );
            assert_eq!(b.seed, s.seed);
        }
        // Reversed batch composition: still identical per request.
        let rev: Vec<TwinRequest> =
            reqs.iter().rev().cloned().collect();
        let batched_rev = twin.run_batch(&rev);
        for (k, b) in batched_rev.iter().enumerate() {
            assert_eq!(
                b.as_ref().unwrap().trajectory,
                serial[reqs.len() - 1 - k].trajectory,
                "noisy request depends on batch position"
            );
        }
    }

    #[test]
    fn ensemble_members_match_standalone_derived_seeds() {
        use crate::twin::{ensemble_member_seed, EnsembleSpec};
        // One ensemble request = one batched rollout of N noisy lanes;
        // member k must equal a standalone rollout seeded with
        // ensemble_member_seed(seed, k).
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mut twin = HpTwin::analog(&toy_weights(), &cfg, noise, 3);
        let n = 6;
        let req = TwinRequest::driven(
            vec![0.4],
            8,
            Waveform::sine(1.0, 4.0),
        )
        .with_seed(777)
        .with_ensemble(
            EnsembleSpec::new(n)
                .with_percentiles(vec![10.0, 90.0])
                .with_member_trajectories(),
        );
        let resp = twin.run(&req).unwrap();
        assert_eq!(resp.seed, 777);
        let ens = resp.ensemble.as_ref().expect("ensemble stats");
        assert_eq!(ens.members, n);
        assert_eq!(ens.mean.len(), 8);
        assert_eq!(ens.std.len(), 8);
        assert_eq!(ens.percentiles.len(), 2);
        assert_eq!(ens.member_trajectories.len(), n);
        assert_eq!(ens.nan_samples, 0);
        // The response trajectory is the ensemble mean.
        assert_eq!(resp.trajectory, ens.mean);
        for (k, member) in ens.member_trajectories.iter().enumerate() {
            let standalone = twin
                .run(
                    &TwinRequest::driven(
                        vec![0.4],
                        8,
                        Waveform::sine(1.0, 4.0),
                    )
                    .with_seed(ensemble_member_seed(777, k as u64)),
                )
                .unwrap();
            assert_eq!(
                *member, standalone.trajectory,
                "member {k} != standalone derived-seed rollout"
            );
        }
        // Noise is real: the spread is non-zero past the initial sample.
        assert!(ens.std.row(7)[0] > 0.0);
    }

    #[test]
    fn run_batch_isolates_missing_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let reqs = vec![
            TwinRequest::driven(vec![0.3], 10, Waveform::sine(1.0, 4.0)),
            TwinRequest::autonomous(vec![0.3], 10),
            TwinRequest::driven(vec![0.4], 10, Waveform::sine(1.0, 4.0)),
        ];
        let results = twin.run_batch(&reqs);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        // The good ones still match their serial runs exactly.
        let want0 = twin.run(&reqs[0]).unwrap();
        assert_eq!(
            results[0].as_ref().unwrap().trajectory,
            want0.trajectory
        );
    }
}
