//! The HP-memristor digital twin (Fig. 3).
//!
//! State: the normalised doped-region boundary h = w/D (dim 1). Driven by a
//! voltage stimulus. Backends: analogue solver, Rust RK4, recurrent-ResNet
//! baseline, or the AOT PJRT artifact.
//!
//! The batched request path is allocation-free in steady state: grouping,
//! stimulus/initial-state staging, the rollout itself and the per-request
//! response trajectories all come from reusable scratch owned by the twin
//! (see [`Twin::run_batch_into`] and the perf invariants in `lib.rs`).

use anyhow::{anyhow, Result};

use crate::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use crate::device::taox::DeviceConfig;
use crate::models::loader::MlpWeights;
use crate::models::mlp::{BatchDrivenMlpField, DrivenMlpField, Mlp};
use crate::models::resnet::RecurrentResNet;
use crate::ode::rk4::{self, Rk4};
use crate::twin::{
    assemble_ensemble_stats, ensemble_member_seed, EnsembleStats, GroupPlan,
    RolloutFn, Twin, TwinRequest, TwinResponse, MAX_SUB_BATCH_LANES,
};
use crate::util::rng::{NoiseLane, SeedSequencer};
use crate::util::stats::EnsembleAccumulator;
use crate::util::tensor::{Trajectory, TrajectoryPool};
use crate::workload::stimuli::Waveform;

/// Default circuit substeps per output sample for the analogue backend.
pub const ANALOG_SUBSTEPS: usize = 20;
/// Default RK4 substeps per output sample for the digital backend.
pub const DIGITAL_SUBSTEPS: usize = 1;

/// Auto-seed root for backends built without an explicit seed (digital,
/// resnet, pjrt — the seed is still resolved and echoed for replay).
const HP_AUTO_ROOT: u64 = 0x4870_5eed_0000_0001;

/// Execution backend of the HP twin.
pub enum HpBackend {
    /// Simulated memristive solver at a noise operating point.
    Analog(Box<AnalogNeuralOde>),
    /// Rust-native RK4 over the trained field.
    Digital(Mlp),
    /// Recurrent-ResNet discrete baseline.
    Resnet(RecurrentResNet),
    /// AOT HLO rollout via PJRT (expects the full half-step stimulus).
    Pjrt(RolloutFn),
}

impl HpBackend {
    fn label(&self) -> &'static str {
        match self {
            HpBackend::Analog(_) => "analog",
            HpBackend::Digital(_) => "digital-rk4",
            HpBackend::Resnet(_) => "resnet",
            HpBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// Reusable batch scratch: everything `run_batch_into` needs between the
/// request slice and the response vector lives here so a warm twin never
/// allocates. Taken out of `self` with `mem::take` for the duration of a
/// batch (its `Default` is allocation-free) to sidestep borrow conflicts
/// with the backend.
#[derive(Default)]
struct HpScratch {
    plan: GroupPlan,
    /// One slot per request; drained into the caller's vector in order.
    slots: Vec<Option<Result<TwinResponse>>>,
    /// Valid request indices of the current group (submission order).
    members: Vec<usize>,
    /// First lane slot of each valid request within the group's flat
    /// batch (an ensemble request occupies `lanes()` consecutive slots).
    lane_base: Vec<usize>,
    /// Per-*lane* stimulus / initial state staging (ensemble members
    /// replicate their request's stimulus and h0).
    waves: Vec<Waveform>,
    h0s: Vec<f64>,
    /// Per-request resolved noise seeds (echoed in the responses; an
    /// ensemble's members derive from it via [`ensemble_member_seed`]).
    seeds: Vec<u64>,
    /// Per-lane noise lanes (one per trajectory, rebuilt from seeds).
    lanes: Vec<NoiseLane>,
    /// Flat batched rollout output (rows = one lockstep sample).
    flat: Trajectory,
    /// Response-trajectory pool (refilled via [`HpTwin::recycle`]).
    pool: TrajectoryPool,
    /// Streaming ensemble moment accumulator (pooled output buffers).
    acc: EnsembleAccumulator,
    /// Recycled [`EnsembleStats`] container shells.
    ens_shells: Vec<EnsembleStats>,
    solver: HpSolverScratch,
}

/// Digital-backend solver scratch (stage buffers + stacked drive rows).
struct HpSolverScratch {
    rk4: Rk4,
    u: Vec<f64>,
}

impl Default for HpSolverScratch {
    fn default() -> Self {
        Self { rk4: Rk4::new(0), u: Vec::new() }
    }
}

/// The HP-memristor twin.
pub struct HpTwin {
    backend: HpBackend,
    dt: f64,
    /// Auto-seed source for requests without an explicit noise seed.
    seeds: SeedSequencer,
    scratch: HpScratch,
}

impl HpTwin {
    /// Build the analogue-backend twin from trained weights.
    pub fn analog(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let mlp = AnalogMlp::deploy(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let ode =
            AnalogNeuralOde::new(mlp, 1, dt / ANALOG_SUBSTEPS as f64);
        Self {
            backend: HpBackend::Analog(Box::new(ode)),
            dt,
            seeds: SeedSequencer::new(seed),
            scratch: HpScratch::default(),
        }
    }

    /// Build the digital (Rust RK4) twin.
    pub fn digital(weights: &MlpWeights) -> Self {
        Self {
            backend: HpBackend::Digital(Mlp::from_weights(weights)),
            dt: weights.dt,
            seeds: SeedSequencer::new(HP_AUTO_ROOT),
            scratch: HpScratch::default(),
        }
    }

    /// Build the recurrent-ResNet baseline twin.
    pub fn resnet(weights: &MlpWeights) -> Self {
        Self {
            backend: HpBackend::Resnet(RecurrentResNet::new(
                Mlp::from_weights(weights),
            )),
            dt: weights.dt,
            seeds: SeedSequencer::new(HP_AUTO_ROOT),
            scratch: HpScratch::default(),
        }
    }

    /// Build the PJRT-artifact twin.
    pub fn pjrt(rollout: RolloutFn, dt: f64) -> Self {
        Self {
            backend: HpBackend::Pjrt(rollout),
            dt,
            seeds: SeedSequencer::new(HP_AUTO_ROOT),
            scratch: HpScratch::default(),
        }
    }

    /// Return a response's trajectory buffers to the twin's pool
    /// (ensemble responses hand back every stats trajectory plus the
    /// emptied container shell).
    ///
    /// Optional: callers that hand responses back make the next
    /// `run_batch` draw its output trajectories from the pool instead of
    /// the allocator — the zero-allocation steady state the allocation
    /// test (`rust/tests/alloc.rs`) pins down.
    pub fn recycle(&mut self, mut resp: TwinResponse) {
        if let Some(mut ens) = resp.ensemble.take() {
            ens.reclaim(&mut self.scratch.pool);
            self.scratch.ens_shells.push(ens);
        }
        self.scratch.pool.put(resp.trajectory);
    }

    /// Simulate under a stimulus; returns the scalar state trajectory.
    /// Noise draws come from the next auto-derived lane; use
    /// [`Twin::run`] with a seeded request for replayable rollouts.
    pub fn simulate(
        &mut self,
        wave: &Waveform,
        h0: f64,
        n_points: usize,
    ) -> Result<Vec<f64>> {
        let mut lane = NoiseLane::from_seed(self.seeds.next_seed());
        self.simulate_lane(wave, h0, n_points, &mut lane)
    }

    /// [`HpTwin::simulate`] drawing noise from an explicit trajectory
    /// lane — the replayable request path.
    fn simulate_lane(
        &mut self,
        wave: &Waveform,
        h0: f64,
        n_points: usize,
        lane: &mut NoiseLane,
    ) -> Result<Vec<f64>> {
        let dt = self.dt;
        match &mut self.backend {
            HpBackend::Analog(ode) => {
                let w = *wave;
                let mut traj = Trajectory::new(1);
                ode.solve_into(
                    &[h0],
                    &mut |t, x: &mut [f64]| x[0] = w.eval(t),
                    dt,
                    n_points,
                    lane,
                    &mut traj,
                );
                Ok(traj.into_data())
            }
            HpBackend::Digital(mlp) => {
                let w = *wave;
                let mut field = DrivenMlpField::new(
                    mlp,
                    move |t| w.eval(t),
                    "hp/digital",
                );
                let traj = rk4::solve(
                    &mut field,
                    &[h0],
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                );
                Ok(traj.into_data())
            }
            HpBackend::Resnet(resnet) => {
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| vec![wave.eval(k as f64 * dt)])
                    .collect();
                let traj = resnet.rollout(&[h0], &xs);
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
            HpBackend::Pjrt(rollout) => {
                let xs_half = wave.sample_half_steps(n_points, dt);
                let traj = rollout(&[h0], Some(&xs_half))?;
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
        }
    }

    /// Batched simulation of one compatible sub-batch into `out` (flat
    /// rows of width `batch`): all trajectories share `n_points` but carry
    /// their own stimulus and initial state. Analog and Digital backends
    /// are allocation-free with warm scratch (one device read / GEMM per
    /// step for the whole batch); Resnet runs a true batched rollout with
    /// staging allocations. With per-trajectory noise lanes the batched
    /// trajectories are bit-identical to serial ones — noise on or off.
    /// Pjrt is handled by the caller's serial fallback.
    fn simulate_batch_flat(
        &mut self,
        waves: &[Waveform],
        h0s: &[f64],
        n_points: usize,
        solver: &mut HpSolverScratch,
        lanes: &mut [NoiseLane],
        out: &mut Trajectory,
    ) -> Result<()> {
        let batch = waves.len();
        debug_assert_eq!(h0s.len(), batch);
        let dt = self.dt;
        match &mut self.backend {
            HpBackend::Analog(ode) => {
                ode.solve_batch_into(
                    h0s,
                    batch,
                    &mut |b, t, x: &mut [f64]| x[0] = waves[b].eval(t),
                    dt,
                    n_points,
                    lanes,
                    out,
                );
                Ok(())
            }
            HpBackend::Digital(mlp) => {
                let mut field = BatchDrivenMlpField::new(
                    mlp,
                    batch,
                    |b, t| waves[b].eval(t),
                    &mut solver.u,
                    "hp/digital",
                );
                rk4::solve_batch_into(
                    &mut field,
                    h0s,
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                    &mut solver.rk4,
                    out,
                );
                Ok(())
            }
            HpBackend::Resnet(resnet) => {
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| {
                        waves
                            .iter()
                            .map(|w| w.eval(k as f64 * dt))
                            .collect()
                    })
                    .collect();
                let trajs = resnet.rollout_batch(h0s, batch, &xs);
                out.reset(batch);
                out.reserve_rows(n_points.max(1));
                for k in 0..trajs.first().map_or(0, Vec::len) {
                    out.push_row_from_iter(
                        (0..batch).map(|b| trajs[b][k][0]),
                    );
                }
                Ok(())
            }
            HpBackend::Pjrt(_) => {
                unreachable!("pjrt uses the serial fallback")
            }
        }
    }
}

impl Twin for HpTwin {
    fn name(&self) -> &str {
        "hp"
    }

    fn state_dim(&self) -> usize {
        1
    }

    fn dt(&self) -> f64 {
        self.dt
    }

    fn default_h0(&self) -> Vec<f64> {
        vec![crate::device::hp::H0]
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        if req.ensemble.is_some() {
            // Ensembles always execute as one batched rollout, even when
            // submitted serially (one request = one sub-batch of N lanes).
            let mut out = Vec::with_capacity(1);
            self.run_batch_into(std::slice::from_ref(req), &mut out);
            return out.pop().expect("one result per request");
        }
        let wave = req
            .stimulus
            .ok_or_else(|| anyhow!("hp twin requires a stimulus"))?;
        let h0 = if req.h0.is_empty() {
            crate::device::hp::H0
        } else {
            req.h0[0]
        };
        let backend = self.backend.label();
        let seed = self.seeds.resolve(req.seed);
        let mut lane = NoiseLane::from_seed(seed);
        let h = self.simulate_lane(&wave, h0, req.n_points, &mut lane)?;
        Ok(TwinResponse {
            trajectory: Trajectory::from_data(1, h),
            backend,
            seed,
            ensemble: None,
            degraded: false,
        })
    }

    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        let mut out = Vec::with_capacity(reqs.len());
        self.run_batch_into(reqs, &mut out);
        out
    }

    /// Batched execution: requests are split into compatible sub-batches
    /// (same `n_points`, lane-counted capacity; stimulus and h0 are
    /// per-trajectory) and each sub-batch runs as one batched rollout. An
    /// ensemble request expands into `EnsembleSpec::members` noise lanes
    /// (member `k` seeded by [`ensemble_member_seed`]) inside that single
    /// rollout, and its response carries pooled [`EnsembleStats`].
    /// Requests without a stimulus (or with an invalid ensemble spec) fail
    /// individually without poisoning the batch. All bookkeeping and the
    /// response trajectories come from the twin's reusable scratch.
    fn run_batch_into(
        &mut self,
        reqs: &[TwinRequest],
        out: &mut Vec<Result<TwinResponse>>,
    ) {
        let backend = self.backend.label();
        let mut sc = std::mem::take(&mut self.scratch);
        sc.plan.plan_lanes(reqs, MAX_SUB_BATCH_LANES);
        sc.slots.clear();
        sc.slots.resize_with(reqs.len(), || None);
        for g in 0..sc.plan.n_groups() {
            let n_points = reqs[sc.plan.group(g)[0]].n_points;
            sc.members.clear();
            sc.lane_base.clear();
            sc.waves.clear();
            sc.h0s.clear();
            sc.seeds.clear();
            sc.lanes.clear();
            for &i in sc.plan.group(g) {
                let wave = match reqs[i].stimulus {
                    Some(w) => w,
                    None => {
                        sc.slots[i] = Some(Err(anyhow!(
                            "hp twin requires a stimulus"
                        )));
                        continue;
                    }
                };
                if let Some(spec) = &reqs[i].ensemble {
                    if let Err(e) = spec.validate() {
                        sc.slots[i] = Some(Err(e));
                        continue;
                    }
                }
                let h0 = if reqs[i].h0.is_empty() {
                    crate::device::hp::H0
                } else {
                    reqs[i].h0[0]
                };
                let seed = self.seeds.resolve(reqs[i].seed);
                sc.members.push(i);
                sc.lane_base.push(sc.lanes.len());
                sc.seeds.push(seed);
                if reqs[i].ensemble.is_some() {
                    for m in 0..reqs[i].lanes() {
                        sc.waves.push(wave);
                        sc.h0s.push(h0);
                        sc.lanes.push(NoiseLane::from_seed(
                            ensemble_member_seed(seed, m as u64),
                        ));
                    }
                } else {
                    sc.waves.push(wave);
                    sc.h0s.push(h0);
                    sc.lanes.push(NoiseLane::from_seed(seed));
                }
            }
            if sc.members.is_empty() {
                continue;
            }
            if matches!(self.backend, HpBackend::Pjrt(_)) {
                // No batched artifact path yet: per-trajectory rollouts
                // (and therefore no single-rollout ensemble expansion).
                for k in 0..sc.members.len() {
                    let i = sc.members[k];
                    if reqs[i].ensemble.is_some() {
                        sc.slots[i] = Some(Err(anyhow!(
                            "ensemble requests are not supported on the \
                             pjrt backend"
                        )));
                        continue;
                    }
                    let base = sc.lane_base[k];
                    let seed = sc.seeds[k];
                    let r = self
                        .simulate_lane(
                            &sc.waves[base],
                            sc.h0s[base],
                            n_points,
                            &mut sc.lanes[base],
                        )
                        .map(|h| TwinResponse {
                            trajectory: Trajectory::from_data(1, h),
                            backend,
                            seed,
                            ensemble: None,
                            degraded: false,
                        });
                    sc.slots[i] = Some(r);
                }
                continue;
            }
            match self.simulate_batch_flat(
                &sc.waves,
                &sc.h0s,
                n_points,
                &mut sc.solver,
                &mut sc.lanes,
                &mut sc.flat,
            ) {
                Ok(()) => {
                    let batch = sc.waves.len();
                    for (k, &i) in sc.members.iter().enumerate() {
                        let base = sc.lane_base[k];
                        match &reqs[i].ensemble {
                            None => {
                                let mut t = sc.pool.get(1);
                                crate::ode::batch::unbatch_into(
                                    &sc.flat, batch, 1, base, &mut t,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: None,
                                    degraded: false,
                                }));
                            }
                            Some(spec) => {
                                let shell = sc
                                    .ens_shells
                                    .pop()
                                    .unwrap_or_default();
                                let (t, stats) = assemble_ensemble_stats(
                                    spec,
                                    &sc.flat,
                                    crate::twin::EnsembleSlot {
                                        batch,
                                        dim: 1,
                                        base,
                                    },
                                    &mut sc.acc,
                                    &mut sc.pool,
                                    shell,
                                );
                                sc.slots[i] = Some(Ok(TwinResponse {
                                    trajectory: t,
                                    backend,
                                    seed: sc.seeds[k],
                                    ensemble: Some(stats),
                                    degraded: false,
                                }));
                            }
                        }
                    }
                }
                Err(e) => {
                    // Group-level failure: broadcast without touching
                    // other groups.
                    let msg = format!("{e:#}");
                    for &i in &sc.members {
                        sc.slots[i] = Some(Err(anyhow!(msg.clone())));
                    }
                }
            }
        }
        for s in sc.slots.drain(..) {
            out.push(s.expect("every request receives a result"));
        }
        self.scratch = sc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::hp;
    use crate::metrics::mre::mre;
    use crate::util::tensor::Mat;

    /// Trained-ish weights: use the *true* field via a fine ReLU net is
    /// overkill for unit tests — instead check plumbing with a hand-made
    /// linear field f([v; h]) = 2v - h (exact via paired ReLUs).
    fn toy_weights() -> MlpWeights {
        let w1 = Mat::from_vec(
            2,
            4,
            vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
        );
        let b1 = vec![0.0; 4];
        let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
        let b2 = vec![0.0];
        MlpWeights {
            layers: vec![(w1, b1), (w2, b2)],
            dt: 1e-3,
            kind: "node".into(),
            task: "hp".into(),
        }
    }

    #[test]
    fn digital_twin_solves_linear_driven_ode() {
        let mut twin = HpTwin::digital(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, 0.5, 100).unwrap();
        assert_eq!(h.len(), 100);
        assert_eq!(h[0], 0.5);
        assert!(h.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn analog_and_digital_agree_on_toy_field() {
        let w = toy_weights();
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut ana = HpTwin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut dig = HpTwin::digital(&w);
        let wave = Waveform::sine(1.0, 4.0);
        let ha = ana.simulate(&wave, 0.2, 200).unwrap();
        let hd = dig.simulate(&wave, 0.2, 200).unwrap();
        let err = mre(&ha, &hd);
        assert!(err < 0.05, "analog vs digital MRE {err}");
    }

    #[test]
    fn twin_trait_requires_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::autonomous(vec![], 10);
        assert!(twin.run(&req).is_err());
    }

    #[test]
    fn twin_trait_roundtrip() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::driven(
            vec![0.3],
            50,
            Waveform::triangular(1.0, 4.0),
        );
        let resp = twin.run(&req).unwrap();
        assert_eq!(resp.trajectory.len(), 50);
        assert_eq!(resp.trajectory.dim(), 1);
        assert_eq!(resp.backend, "digital-rk4");
        assert_eq!(resp.trajectory.row(0), [0.3]);
    }

    #[test]
    fn resnet_backend_rolls_out() {
        let mut twin = HpTwin::resnet(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, hp::H0, 20).unwrap();
        assert_eq!(h.len(), 20);
    }

    fn mixed_requests() -> Vec<TwinRequest> {
        vec![
            TwinRequest::driven(vec![0.3], 40, Waveform::sine(1.0, 4.0)),
            TwinRequest::driven(
                vec![0.5],
                25,
                Waveform::triangular(1.0, 4.0),
            ),
            TwinRequest::driven(
                vec![0.2],
                40,
                Waveform::rectangular(1.0, 4.0),
            ),
            TwinRequest::driven(vec![], 40, Waveform::modulated(1.0, 4.0, 1.0)),
        ]
    }

    fn assert_batch_matches_serial(twin: &mut HpTwin) {
        let reqs = mixed_requests();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
            assert_eq!(b.backend, s.backend);
        }
        // A second pass on the now-warm scratch must agree too (pooled
        // buffers never leak stale samples).
        for (resp, s) in twin.run_batch(&reqs).into_iter().zip(&serial) {
            let resp = resp.unwrap();
            assert_eq!(resp.trajectory, s.trajectory);
            twin.recycle(resp);
        }
        let third = twin.run_batch(&reqs);
        for (b, s) in third.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap().trajectory, s.trajectory);
        }
    }

    #[test]
    fn digital_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::digital(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn analog_run_batch_bit_identical_to_serial_noise_free() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin =
            HpTwin::analog(&toy_weights(), &cfg, AnalogNoise::off(), 3);
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn resnet_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::resnet(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn seeded_noisy_run_replays_and_matches_batched() {
        // With read noise ON, a pinned seed makes the rollout replayable
        // and batch-position independent; the response echoes the seed.
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mut twin = HpTwin::analog(&toy_weights(), &cfg, noise, 3);
        let reqs: Vec<TwinRequest> = (0..3)
            .map(|k| {
                TwinRequest::driven(
                    vec![0.2 + 0.1 * k as f64],
                    10,
                    Waveform::sine(1.0, 4.0),
                )
                .with_seed(500 + k as u64)
            })
            .collect();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        for (r, s) in reqs.iter().zip(&serial) {
            assert_eq!(s.seed, r.seed.unwrap(), "seed not echoed");
            // Replay on the same twin: bit-identical.
            let again = twin.run(r).unwrap();
            assert_eq!(again.trajectory, s.trajectory, "replay diverged");
        }
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(
                b.trajectory, s.trajectory,
                "noisy request {k}: batched != serial"
            );
            assert_eq!(b.seed, s.seed);
        }
        // Reversed batch composition: still identical per request.
        let rev: Vec<TwinRequest> =
            reqs.iter().rev().cloned().collect();
        let batched_rev = twin.run_batch(&rev);
        for (k, b) in batched_rev.iter().enumerate() {
            assert_eq!(
                b.as_ref().unwrap().trajectory,
                serial[reqs.len() - 1 - k].trajectory,
                "noisy request depends on batch position"
            );
        }
    }

    #[test]
    fn ensemble_members_match_standalone_derived_seeds() {
        use crate::twin::{ensemble_member_seed, EnsembleSpec};
        // One ensemble request = one batched rollout of N noisy lanes;
        // member k must equal a standalone rollout seeded with
        // ensemble_member_seed(seed, k).
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mut twin = HpTwin::analog(&toy_weights(), &cfg, noise, 3);
        let n = 6;
        let req = TwinRequest::driven(
            vec![0.4],
            8,
            Waveform::sine(1.0, 4.0),
        )
        .with_seed(777)
        .with_ensemble(
            EnsembleSpec::new(n)
                .with_percentiles(vec![10.0, 90.0])
                .with_member_trajectories(),
        );
        let resp = twin.run(&req).unwrap();
        assert_eq!(resp.seed, 777);
        let ens = resp.ensemble.as_ref().expect("ensemble stats");
        assert_eq!(ens.members, n);
        assert_eq!(ens.mean.len(), 8);
        assert_eq!(ens.std.len(), 8);
        assert_eq!(ens.percentiles.len(), 2);
        assert_eq!(ens.member_trajectories.len(), n);
        assert_eq!(ens.nan_samples, 0);
        // The response trajectory is the ensemble mean.
        assert_eq!(resp.trajectory, ens.mean);
        for (k, member) in ens.member_trajectories.iter().enumerate() {
            let standalone = twin
                .run(
                    &TwinRequest::driven(
                        vec![0.4],
                        8,
                        Waveform::sine(1.0, 4.0),
                    )
                    .with_seed(ensemble_member_seed(777, k as u64)),
                )
                .unwrap();
            assert_eq!(
                *member, standalone.trajectory,
                "member {k} != standalone derived-seed rollout"
            );
        }
        // Noise is real: the spread is non-zero past the initial sample.
        assert!(ens.std.row(7)[0] > 0.0);
    }

    #[test]
    fn run_batch_isolates_missing_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let reqs = vec![
            TwinRequest::driven(vec![0.3], 10, Waveform::sine(1.0, 4.0)),
            TwinRequest::autonomous(vec![0.3], 10),
            TwinRequest::driven(vec![0.4], 10, Waveform::sine(1.0, 4.0)),
        ];
        let results = twin.run_batch(&reqs);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        // The good ones still match their serial runs exactly.
        let want0 = twin.run(&reqs[0]).unwrap();
        assert_eq!(
            results[0].as_ref().unwrap().trajectory,
            want0.trajectory
        );
    }
}
