//! The HP-memristor digital twin (Fig. 3).
//!
//! State: the normalised doped-region boundary h = w/D (dim 1). Driven by a
//! voltage stimulus. Backends: analogue solver, Rust RK4, recurrent-ResNet
//! baseline, or the AOT PJRT artifact.

use anyhow::{anyhow, Result};

use crate::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use crate::device::taox::DeviceConfig;
use crate::models::loader::MlpWeights;
use crate::models::mlp::{BatchDrivenMlpField, DrivenMlpField, Mlp};
use crate::models::resnet::RecurrentResNet;
use crate::ode::rk4;
use crate::twin::{
    run_batch_grouped, RolloutFn, Twin, TwinRequest, TwinResponse,
};
use crate::workload::stimuli::Waveform;

/// Default circuit substeps per output sample for the analogue backend.
pub const ANALOG_SUBSTEPS: usize = 20;
/// Default RK4 substeps per output sample for the digital backend.
pub const DIGITAL_SUBSTEPS: usize = 1;

/// Execution backend of the HP twin.
pub enum HpBackend {
    /// Simulated memristive solver at a noise operating point.
    Analog(Box<AnalogNeuralOde>),
    /// Rust-native RK4 over the trained field.
    Digital(Mlp),
    /// Recurrent-ResNet discrete baseline.
    Resnet(RecurrentResNet),
    /// AOT HLO rollout via PJRT (expects the full half-step stimulus).
    Pjrt(RolloutFn),
}

impl HpBackend {
    fn label(&self) -> &'static str {
        match self {
            HpBackend::Analog(_) => "analog",
            HpBackend::Digital(_) => "digital-rk4",
            HpBackend::Resnet(_) => "resnet",
            HpBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// The HP-memristor twin.
pub struct HpTwin {
    backend: HpBackend,
    dt: f64,
}

impl HpTwin {
    /// Build the analogue-backend twin from trained weights.
    pub fn analog(
        weights: &MlpWeights,
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        let layers: Vec<LayerWeights> = weights
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let mlp = AnalogMlp::deploy(&layers, cfg, noise, seed);
        let dt = weights.dt;
        let ode =
            AnalogNeuralOde::new(mlp, 1, dt / ANALOG_SUBSTEPS as f64);
        Self { backend: HpBackend::Analog(Box::new(ode)), dt }
    }

    /// Build the digital (Rust RK4) twin.
    pub fn digital(weights: &MlpWeights) -> Self {
        Self {
            backend: HpBackend::Digital(Mlp::from_weights(weights)),
            dt: weights.dt,
        }
    }

    /// Build the recurrent-ResNet baseline twin.
    pub fn resnet(weights: &MlpWeights) -> Self {
        Self {
            backend: HpBackend::Resnet(RecurrentResNet::new(
                Mlp::from_weights(weights),
            )),
            dt: weights.dt,
        }
    }

    /// Build the PJRT-artifact twin.
    pub fn pjrt(rollout: RolloutFn, dt: f64) -> Self {
        Self { backend: HpBackend::Pjrt(rollout), dt }
    }

    /// Simulate under a stimulus; returns the scalar state trajectory.
    pub fn simulate(
        &mut self,
        wave: &Waveform,
        h0: f64,
        n_points: usize,
    ) -> Result<Vec<f64>> {
        let dt = self.dt;
        match &mut self.backend {
            HpBackend::Analog(ode) => {
                let w = *wave;
                let traj = ode.solve(
                    &[h0],
                    &mut |t| vec![w.eval(t)],
                    dt,
                    n_points,
                );
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
            HpBackend::Digital(mlp) => {
                let w = *wave;
                let mut field =
                    DrivenMlpField::new(mlp.clone(), move |t| w.eval(t));
                let traj = rk4::solve(
                    &mut field,
                    &[h0],
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                );
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
            HpBackend::Resnet(resnet) => {
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| vec![wave.eval(k as f64 * dt)])
                    .collect();
                let traj = resnet.rollout(&[h0], &xs);
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
            HpBackend::Pjrt(rollout) => {
                let xs_half = wave.sample_half_steps(n_points, dt);
                let traj = rollout(&[h0], Some(&xs_half))?;
                Ok(traj.into_iter().map(|r| r[0]).collect())
            }
        }
    }

    /// Batched simulation of one compatible sub-batch: all trajectories
    /// share `n_points` but carry their own stimulus and initial state.
    /// Analog, Digital and Resnet backends run a true batched rollout (one
    /// device read / GEMM per step for the whole batch); Pjrt falls back to
    /// per-trajectory [`HpTwin::simulate`]. With noise off the batched
    /// trajectories are bit-identical to serial ones.
    pub fn simulate_batch(
        &mut self,
        waves: &[Waveform],
        h0s: &[f64],
        n_points: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let batch = waves.len();
        anyhow::ensure!(
            h0s.len() == batch,
            "simulate_batch: {} initial states for {} stimuli",
            h0s.len(),
            batch
        );
        if matches!(self.backend, HpBackend::Pjrt(_)) {
            return waves
                .iter()
                .zip(h0s)
                .map(|(w, &h0)| self.simulate(w, h0, n_points))
                .collect();
        }
        let dt = self.dt;
        match &mut self.backend {
            HpBackend::Analog(ode) => {
                let ws = waves.to_vec();
                let trajs = ode.solve_batch(
                    h0s,
                    batch,
                    &mut |b, t, x| x[0] = ws[b].eval(t),
                    dt,
                    n_points,
                );
                Ok(trajs
                    .into_iter()
                    .map(|tr| tr.into_iter().map(|r| r[0]).collect())
                    .collect())
            }
            HpBackend::Digital(mlp) => {
                let ws = waves.to_vec();
                let mut field = BatchDrivenMlpField::new(
                    mlp.clone(),
                    batch,
                    move |b, t| ws[b].eval(t),
                );
                let flat = rk4::solve_batch(
                    &mut field,
                    h0s,
                    dt,
                    n_points,
                    DIGITAL_SUBSTEPS,
                );
                Ok((0..batch)
                    .map(|b| flat.iter().map(|row| row[b]).collect())
                    .collect())
            }
            HpBackend::Resnet(resnet) => {
                let xs: Vec<Vec<f64>> = (0..n_points.saturating_sub(1))
                    .map(|k| {
                        waves
                            .iter()
                            .map(|w| w.eval(k as f64 * dt))
                            .collect()
                    })
                    .collect();
                let trajs = resnet.rollout_batch(h0s, batch, &xs);
                Ok(trajs
                    .into_iter()
                    .map(|tr| {
                        tr.into_iter().map(|r| r[0]).collect::<Vec<f64>>()
                    })
                    .collect())
            }
            HpBackend::Pjrt(_) => unreachable!("handled above"),
        }
    }
}

impl Twin for HpTwin {
    fn name(&self) -> &str {
        "hp"
    }

    fn state_dim(&self) -> usize {
        1
    }

    fn dt(&self) -> f64 {
        self.dt
    }

    fn default_h0(&self) -> Vec<f64> {
        vec![crate::device::hp::H0]
    }

    fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
        let wave = req
            .stimulus
            .ok_or_else(|| anyhow!("hp twin requires a stimulus"))?;
        let h0 = if req.h0.is_empty() {
            crate::device::hp::H0
        } else {
            req.h0[0]
        };
        let backend = self.backend.label().to_string();
        let h = self.simulate(&wave, h0, req.n_points)?;
        Ok(TwinResponse {
            trajectory: h.into_iter().map(|v| vec![v]).collect(),
            backend,
        })
    }

    /// Batched execution: requests are split into compatible sub-batches
    /// (same `n_points`; stimulus and h0 are per-trajectory) and each
    /// sub-batch runs as one batched rollout. Requests without a stimulus
    /// fail individually without poisoning the batch.
    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<Result<TwinResponse>> {
        let backend = self.backend.label().to_string();
        run_batch_grouped(
            reqs,
            |req| match req.stimulus {
                Some(w) => Ok((
                    w,
                    if req.h0.is_empty() {
                        crate::device::hp::H0
                    } else {
                        req.h0[0]
                    },
                )),
                None => Err(anyhow!("hp twin requires a stimulus")),
            },
            |items, n_points| {
                let waves: Vec<Waveform> =
                    items.iter().map(|&(w, _)| w).collect();
                let h0s: Vec<f64> =
                    items.iter().map(|&(_, h0)| h0).collect();
                let trajs = self.simulate_batch(&waves, &h0s, n_points)?;
                Ok(trajs
                    .into_iter()
                    .map(|h| TwinResponse {
                        trajectory: h
                            .into_iter()
                            .map(|v| vec![v])
                            .collect(),
                        backend: backend.clone(),
                    })
                    .collect())
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::hp;
    use crate::metrics::mre::mre;
    use crate::util::tensor::Mat;

    /// Trained-ish weights: use the *true* field via a fine ReLU net is
    /// overkill for unit tests — instead check plumbing with a hand-made
    /// linear field f([v; h]) = 2v - h (exact via paired ReLUs).
    fn toy_weights() -> MlpWeights {
        let w1 = Mat::from_vec(
            2,
            4,
            vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
        );
        let b1 = vec![0.0; 4];
        let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
        let b2 = vec![0.0];
        MlpWeights {
            layers: vec![(w1, b1), (w2, b2)],
            dt: 1e-3,
            kind: "node".into(),
            task: "hp".into(),
        }
    }

    #[test]
    fn digital_twin_solves_linear_driven_ode() {
        let mut twin = HpTwin::digital(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, 0.5, 100).unwrap();
        assert_eq!(h.len(), 100);
        assert_eq!(h[0], 0.5);
        assert!(h.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn analog_and_digital_agree_on_toy_field() {
        let w = toy_weights();
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut ana = HpTwin::analog(&w, &cfg, AnalogNoise::off(), 1);
        let mut dig = HpTwin::digital(&w);
        let wave = Waveform::sine(1.0, 4.0);
        let ha = ana.simulate(&wave, 0.2, 200).unwrap();
        let hd = dig.simulate(&wave, 0.2, 200).unwrap();
        let err = mre(&ha, &hd);
        assert!(err < 0.05, "analog vs digital MRE {err}");
    }

    #[test]
    fn twin_trait_requires_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::autonomous(vec![], 10);
        assert!(twin.run(&req).is_err());
    }

    #[test]
    fn twin_trait_roundtrip() {
        let mut twin = HpTwin::digital(&toy_weights());
        let req = TwinRequest::driven(
            vec![0.3],
            50,
            Waveform::triangular(1.0, 4.0),
        );
        let resp = twin.run(&req).unwrap();
        assert_eq!(resp.trajectory.len(), 50);
        assert_eq!(resp.backend, "digital-rk4");
        assert_eq!(resp.trajectory[0], vec![0.3]);
    }

    #[test]
    fn resnet_backend_rolls_out() {
        let mut twin = HpTwin::resnet(&toy_weights());
        let wave = Waveform::sine(1.0, 4.0);
        let h = twin.simulate(&wave, hp::H0, 20).unwrap();
        assert_eq!(h.len(), 20);
    }

    fn mixed_requests() -> Vec<TwinRequest> {
        vec![
            TwinRequest::driven(vec![0.3], 40, Waveform::sine(1.0, 4.0)),
            TwinRequest::driven(
                vec![0.5],
                25,
                Waveform::triangular(1.0, 4.0),
            ),
            TwinRequest::driven(
                vec![0.2],
                40,
                Waveform::rectangular(1.0, 4.0),
            ),
            TwinRequest::driven(vec![], 40, Waveform::modulated(1.0, 4.0, 1.0)),
        ]
    }

    fn assert_batch_matches_serial(twin: &mut HpTwin) {
        let reqs = mixed_requests();
        let serial: Vec<_> =
            reqs.iter().map(|r| twin.run(r).unwrap()).collect();
        let batched = twin.run_batch(&reqs);
        for (k, (b, s)) in batched.iter().zip(&serial).enumerate() {
            let b = b.as_ref().unwrap();
            assert_eq!(b.trajectory, s.trajectory, "request {k}");
            assert_eq!(b.backend, s.backend);
        }
    }

    #[test]
    fn digital_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::digital(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn analog_run_batch_bit_identical_to_serial_noise_free() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut twin =
            HpTwin::analog(&toy_weights(), &cfg, AnalogNoise::off(), 3);
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn resnet_run_batch_bit_identical_to_serial() {
        let mut twin = HpTwin::resnet(&toy_weights());
        assert_batch_matches_serial(&mut twin);
    }

    #[test]
    fn run_batch_isolates_missing_stimulus() {
        let mut twin = HpTwin::digital(&toy_weights());
        let reqs = vec![
            TwinRequest::driven(vec![0.3], 10, Waveform::sine(1.0, 4.0)),
            TwinRequest::autonomous(vec![0.3], 10),
            TwinRequest::driven(vec![0.4], 10, Waveform::sine(1.0, 4.0)),
        ];
        let results = twin.run_batch(&reqs);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        // The good ones still match their serial runs exactly.
        let want0 = twin.run(&reqs[0]).unwrap();
        assert_eq!(
            results[0].as_ref().unwrap().trajectory,
            want0.trajectory
        );
    }
}
