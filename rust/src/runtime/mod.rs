//! PJRT runtime: loads and executes the AOT HLO artifacts.
//!
//! The compile path (`make artifacts`) lowers the JAX/Pallas model to HLO
//! *text* once; this module makes those artifacts callable from the Rust
//! request path:
//!
//! * [`client`]    — the thread-local runtime: `PjRtClient::cpu()` ->
//!   `HloModuleProto::from_text_file` -> `compile` -> `execute`
//! * [`service`]   — a dedicated runtime thread + `Send` handle (the `xla`
//!   crate's client is `Rc`-based and not `Send`; the coordinator's worker
//!   threads talk to it over channels)
//! * [`artifacts`] — `manifest.json` parsing and twin-facing rollout
//!   closures
//!
//! Note on interchange: HLO text, **not** serialized `HloModuleProto` —
//! jax >= 0.5 emits protos with 64-bit instruction ids that the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifacts;
pub mod client;
pub mod service;
#[cfg(feature = "pjrt")]
pub mod xla_offline;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
pub use client::PjrtRuntime;
pub use service::{PjrtHandle, PjrtService};

/// A shaped f32 tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "tensor shape/data mismatch"
        );
        Self { shape, data }
    }

    /// Build from f64 host data (the simulator's native precision).
    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Self {
        Self::new(shape, data.iter().map(|&x| x as f32).collect())
    }

    /// Rows of a rank-2 tensor as f64 (trajectory unpacking).
    pub fn rows_f64(&self) -> Vec<Vec<f64>> {
        assert_eq!(self.shape.len(), 2, "rows_f64 needs rank 2");
        let (n, d) = (self.shape[0], self.shape[1]);
        (0..n)
            .map(|r| {
                self.data[r * d..(r + 1) * d]
                    .iter()
                    .map(|&x| x as f64)
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn bad_shape_panics() {
        let _ = TensorF32::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn f64_roundtrip_and_rows() {
        let t = TensorF32::from_f64(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let rows = t.rows_f64();
        assert_eq!(rows, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }
}
