//! The thread-local PJRT runtime.
//!
//! Wraps the `xla` crate: one CPU PJRT client, a cache of compiled
//! executables keyed by artifact name, and shaped-tensor execute. Not
//! `Send` (the client is `Rc`-based) — cross-thread access goes through
//! [`crate::runtime::service::PjrtService`].
//!
//! The `xla` crate needs the xla_extension C++ bundle at build time, so
//! the real implementation is gated behind the non-default `pjrt` cargo
//! feature and compiled against [`crate::runtime::xla_offline`], an
//! offline substitute mirroring the API slice used here — the
//! feature-matrix CI job builds it so this glue can no longer rot
//! silently. Its client refuses to start (vendor the real crate and swap
//! the import to execute artifacts). Without the feature an
//! API-compatible stub is compiled instead: it still validates the
//! artifacts directory (so error paths and hints behave the same) but
//! refuses to start, and every caller — the service thread, the CLI, the
//! examples — degrades gracefully exactly as when artifacts are missing.

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::BTreeMap;
    use std::path::Path;

    use anyhow::{anyhow, Result};

    use crate::runtime::artifacts::ArtifactManifest;
    use crate::runtime::TensorF32;
    // The PJRT surface. The offline substitute type-checks this whole
    // module (CI's feature-matrix job builds `--features pjrt`) while its
    // client constructor fails at runtime; vendoring the real `xla` crate
    // and swapping this import enables actual execution.
    use crate::runtime::xla_offline as xla;

    /// A compiled artifact plus its manifest shapes.
    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        inputs: Vec<Vec<usize>>,
        output: Vec<usize>,
    }

    /// Thread-local PJRT runtime over one artifacts directory.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        manifest: ArtifactManifest,
        compiled: BTreeMap<String, Compiled>,
    }

    impl PjrtRuntime {
        /// Create a CPU runtime for an artifacts directory (reads the
        /// manifest; compilation is lazy per artifact).
        pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
            Ok(Self { client, manifest, compiled: BTreeMap::new() })
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an artifact (no-op if cached).
        pub fn load(&mut self, name: &str) -> Result<()> {
            if self.compiled.contains_key(name) {
                return Ok(());
            }
            let meta = self.manifest.get(name)?.clone();
            let path = self.manifest.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            let output = meta
                .outputs
                .first()
                .cloned()
                .ok_or_else(|| anyhow!("{name}: no outputs in manifest"))?;
            self.compiled.insert(
                name.to_string(),
                Compiled { exe, inputs: meta.inputs, output },
            );
            Ok(())
        }

        /// Names of all artifacts in the manifest.
        pub fn artifact_names(&self) -> Vec<String> {
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
        }

        /// Execute a compiled artifact on shaped f32 inputs; returns the
        /// payload tensor (entries are lowered as 1-tuples).
        pub fn execute(
            &mut self,
            name: &str,
            inputs: &[TensorF32],
        ) -> Result<TensorF32> {
            self.load(name)?;
            let c = self.compiled.get(name).expect("just loaded");
            anyhow::ensure!(
                inputs.len() == c.inputs.len(),
                "{name}: got {} inputs, artifact takes {}",
                inputs.len(),
                c.inputs.len()
            );
            for (k, (t, want)) in inputs.iter().zip(&c.inputs).enumerate() {
                anyhow::ensure!(
                    &t.shape == want,
                    "{name}: input {k} shape {:?} != compiled {:?}",
                    t.shape,
                    want
                );
            }
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> =
                        t.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshaping input: {e}"))
                })
                .collect::<Result<_>>()?;
            let result = c
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e}"))?;
            let payload = result
                .to_tuple1()
                .map_err(|e| anyhow!("unwrapping 1-tuple: {e}"))?;
            let data = payload
                .to_vec::<f32>()
                .map_err(|e| anyhow!("reading f32 payload: {e}"))?;
            anyhow::ensure!(
                data.len() == c.output.iter().product::<usize>(),
                "{name}: output length {} != manifest shape {:?}",
                data.len(),
                c.output
            );
            Ok(TensorF32::new(c.output.clone(), data))
        }

        /// Convenience: execute with f64 host vectors shaped per the
        /// manifest.
        pub fn execute_f64(
            &mut self,
            name: &str,
            inputs: &[Vec<f64>],
        ) -> Result<TensorF32> {
            self.load(name)?;
            let shapes = self.compiled[name].inputs.clone();
            anyhow::ensure!(inputs.len() == shapes.len(), "input arity");
            let tensors: Vec<TensorF32> = inputs
                .iter()
                .zip(shapes)
                .map(|(v, s)| TensorF32::from_f64(s, v))
                .collect();
            self.execute(name, &tensors)
        }
    }

    impl std::fmt::Debug for PjrtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtRuntime")
                .field("platform", &self.client.platform_name())
                .field("compiled", &self.compiled.keys().collect::<Vec<_>>())
                .finish()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::Result;

    use crate::runtime::artifacts::ArtifactManifest;
    use crate::runtime::TensorF32;

    const DISABLED: &str = "PJRT backend not compiled in: vendor the `xla` \
                            crate and rebuild with `--features pjrt`";

    /// API-compatible stand-in for the PJRT runtime when the `pjrt`
    /// feature is off. `cpu()` still validates the artifacts directory (so
    /// missing-artifact hints are identical to the real path) and then
    /// refuses to start; the remaining methods exist so callers typecheck
    /// but are unreachable because construction always fails.
    #[derive(Debug)]
    pub struct PjrtRuntime {
        manifest: ArtifactManifest,
    }

    impl PjrtRuntime {
        pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
            let _ = ArtifactManifest::load(artifacts_dir)?;
            anyhow::bail!(DISABLED)
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "disabled".to_string()
        }

        pub fn load(&mut self, _name: &str) -> Result<()> {
            anyhow::bail!(DISABLED)
        }

        pub fn artifact_names(&self) -> Vec<String> {
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
        }

        pub fn execute(
            &mut self,
            _name: &str,
            _inputs: &[TensorF32],
        ) -> Result<TensorF32> {
            anyhow::bail!(DISABLED)
        }

        pub fn execute_f64(
            &mut self,
            _name: &str,
            _inputs: &[Vec<f64>],
        ) -> Result<TensorF32> {
            anyhow::bail!(DISABLED)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

// Integration tests (requiring built artifacts) live in rust/tests/;
// nothing here can run without PJRT + artifacts on disk.
#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn missing_dir_fails_with_hint() {
        let err = match PjrtRuntime::cpu(Path::new("/no/such/dir")) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("should fail"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn runtime_smoke_if_artifacts_present() {
        // Runs only when `make artifacts` has been executed.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = PjrtRuntime::cpu(&dir).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
        // l96_step_b1: [6] -> [6].
        let out = rt.execute_f64("l96_step_b1", &[vec![0.5; 6]]).unwrap();
        assert_eq!(out.shape, vec![6]);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
