//! A dedicated PJRT runtime thread with a `Send + Clone` handle.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and must stay on one
//! thread; the coordinator's workers, the examples and the benches instead
//! hold a [`PjrtHandle`] and submit execute requests over an mpsc channel,
//! receiving results on a per-request oneshot-style channel. The service
//! thread shuts down when the last handle is dropped.

use std::path::Path;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::runtime::client::PjrtRuntime;
use crate::runtime::TensorF32;

enum Request {
    Execute {
        name: String,
        inputs: Vec<TensorF32>,
        reply: mpsc::Sender<Result<TensorF32>>,
    },
    Preload {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Manifest {
        reply: mpsc::Sender<Vec<String>>,
    },
}

/// Owner of the runtime thread (keep alive for the service lifetime).
pub struct PjrtService {
    handle: PjrtHandle,
    thread: Option<thread::JoinHandle<()>>,
}

/// Cloneable, `Send` handle for submitting work to the runtime thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Request>,
}

impl PjrtService {
    /// Start the runtime thread over an artifacts directory. Fails fast if
    /// the manifest is unreadable or the PJRT client cannot start.
    pub fn start(artifacts_dir: &Path) -> Result<Self> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let mut rt = match PjrtRuntime::cpu(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { name, inputs, reply } => {
                            let _ =
                                reply.send(rt.execute(&name, &inputs));
                        }
                        Request::Preload { names, reply } => {
                            let r = names
                                .iter()
                                .try_for_each(|n| rt.load(n));
                            let _ = reply.send(r);
                        }
                        Request::Manifest { reply } => {
                            let _ = reply.send(rt.artifact_names());
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawning pjrt thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt thread died during startup"))??;
        Ok(Self { handle: PjrtHandle { tx }, thread: Some(thread) })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        // Close our sender so the thread's recv() errors out once all
        // handles are gone, then join.
        let (tx, _) = mpsc::channel();
        self.handle = PjrtHandle { tx };
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl PjrtHandle {
    /// Execute an artifact on the runtime thread (blocking).
    pub fn execute(
        &self,
        name: &str,
        inputs: Vec<TensorF32>,
    ) -> Result<TensorF32> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("pjrt service stopped"))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped reply"))?
    }

    /// Compile artifacts ahead of serving (warmup).
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Preload {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow!("pjrt service stopped"))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped reply"))?
    }

    /// Artifact names available in the manifest.
    pub fn artifact_names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Manifest { reply })
            .map_err(|_| anyhow!("pjrt service stopped"))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped reply"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_failure_propagates() {
        let err = PjrtService::start(Path::new("/no/such/dir"))
            .err()
            .expect("should fail");
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn service_roundtrip_if_artifacts_present() {
        if cfg!(not(feature = "pjrt")) {
            eprintln!("skipping: built without the pjrt feature");
            return;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let svc = PjrtService::start(&dir).unwrap();
        let h = svc.handle();
        let names = h.artifact_names().unwrap();
        assert!(names.iter().any(|n| n == "l96_step_b1"));
        h.preload(&["l96_step_b1"]).unwrap();
        // Handles work from other threads.
        let h2 = svc.handle();
        let out = std::thread::spawn(move || {
            h2.execute(
                "l96_step_b1",
                vec![TensorF32::from_f64(vec![6], &[0.1; 6])],
            )
        })
        .join()
        .unwrap()
        .unwrap();
        assert_eq!(out.shape, vec![6]);
    }
}
