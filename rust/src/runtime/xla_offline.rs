//! Offline substitute for the `xla` crate's PJRT surface.
//!
//! The real `xla` crate needs the xla_extension C++ bundle at build time,
//! which this repository cannot vendor offline. This module mirrors the
//! exact API slice `runtime::client` uses, so the `pjrt` feature — and
//! with it the real PJRT glue code — **compiles and type-checks in CI**
//! (the feature-matrix job) instead of rotting silently behind a
//! `compile_error!`.
//!
//! Semantics: everything that only shapes data ([`Literal`],
//! [`HloModuleProto`], [`XlaComputation`]) works; [`PjRtClient::cpu`] —
//! the sole way to reach an executable — returns an error, so a
//! `--features pjrt` build degrades at runtime exactly like the
//! feature-off stub (construction fails, callers fall back). To run real
//! artifacts, vendor the `xla` crate and swap the `use … as xla` import in
//! `runtime/client.rs`.

use std::path::Path;

/// Error type standing in for the `xla` crate's.
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const OFFLINE: &str = "offline xla substitute: vendor the `xla` crate \
                       (xla_extension bundle) for a real PJRT runtime";

/// Host-side literal: shaped f32 data.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over host data.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal { data: xs.to_vec(), dims: vec![xs.len() as i64] }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a 1-tuple result — only produced by execution, which the
    /// offline substitute cannot perform.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error(OFFLINE.into()))
    }

    /// Read the payload back — only produced by execution.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error(OFFLINE.into()))
    }

    /// Declared dimensions (diagnostics).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (text form is validated as readable, not parsed).
#[derive(Debug)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error(format!("reading {}: {e}", path.as_ref().display()))
        })?;
        Ok(Self { _text: text })
    }
}

/// A computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// The PJRT client — unconstructible offline.
#[derive(Debug)]
pub struct PjRtClient {
    _unconstructible: std::convert::Infallible,
}

impl PjRtClient {
    /// Always fails offline; the sole constructor, so every downstream
    /// method below is statically unreachable.
    pub fn cpu() -> Result<Self> {
        Err(Error(OFFLINE.into()))
    }

    pub fn platform_name(&self) -> String {
        match self._unconstructible {}
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        match self._unconstructible {}
    }
}

/// A compiled executable — only produced by [`PjRtClient::compile`].
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _unconstructible: std::convert::Infallible,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._unconstructible {}
    }
}

/// A device buffer — only produced by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _unconstructible: std::convert::Infallible,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._unconstructible {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_offline() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("must fail offline"),
        };
        assert!(err.to_string().contains("offline xla substitute"));
    }

    #[test]
    fn literal_shaping_works() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_tuple1().is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
