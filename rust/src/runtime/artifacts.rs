//! Artifact manifest (`artifacts/manifest.json`) parsing and the
//! twin-facing rollout closures.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::runtime::service::PjrtHandle;
use crate::runtime::TensorF32;
use crate::twin::RolloutFn;
use crate::util::json::{self, Json};

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (entries return 1-tuples; outputs[0] is the payload).
    pub outputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    /// Task metadata blocks (dt, dims, splits) as raw JSON.
    pub hp: Json,
    pub l96: Json,
}

fn shapes_from(j: &Json, what: &str) -> Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("{what}: expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_vec_f64()
                .map(|v| v.into_iter().map(|x| x as usize).collect())
                .ok_or_else(|| anyhow!("{what}: bad shape entry"))
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let doc = json::from_file(&path)
            .with_context(|| "run `make artifacts` first")?;
        let mut artifacts = Vec::new();
        for a in doc
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts must be an array"))?
        {
            artifacts.push(ArtifactMeta {
                name: a
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact name"))?
                    .to_string(),
                file: a
                    .req("file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact file"))?
                    .to_string(),
                inputs: shapes_from(a.req("inputs")?, "inputs")?,
                outputs: shapes_from(a.req("outputs")?, "outputs")?,
            });
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            hp: doc.get("hp").cloned().unwrap_or(Json::Null),
            l96: doc.get("l96").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest (have: {})",
                self.artifacts
                    .iter()
                    .map(|a| a.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

/// Build a driven-rollout closure (HP twin) over a PJRT service handle.
///
/// The artifact signature is `(h0: [1], xs_half: [2N+1, 1]) -> [N+1, 1]`.
pub fn driven_rollout_fn(
    handle: PjrtHandle,
    meta: &ArtifactMeta,
) -> RolloutFn {
    let name = meta.name.clone();
    let xs_shape = meta.inputs[1].clone();
    Box::new(move |h0: &[f64], stimulus: Option<&[f64]>| {
        let xs = stimulus
            .ok_or_else(|| anyhow!("driven rollout needs a stimulus"))?;
        anyhow::ensure!(
            xs.len() == xs_shape[0],
            "stimulus length {} != compiled length {} (fixed-shape AOT)",
            xs.len(),
            xs_shape[0]
        );
        let inputs = vec![
            TensorF32::from_f64(vec![h0.len()], h0),
            TensorF32::from_f64(xs_shape.clone(), xs),
        ];
        let out = handle.execute(&name, inputs)?;
        Ok(out.rows_f64())
    })
}

/// Build an autonomous-rollout closure (Lorenz96 twin).
///
/// Artifact signature: `(h0: [d]) -> [N+1, d]`.
pub fn autonomous_rollout_fn(
    handle: PjrtHandle,
    meta: &ArtifactMeta,
) -> RolloutFn {
    let name = meta.name.clone();
    Box::new(move |h0: &[f64], _stimulus: Option<&[f64]>| {
        let inputs = vec![TensorF32::from_f64(vec![h0.len()], h0)];
        let out = handle.execute(&name, inputs)?;
        Ok(out.rows_f64())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn manifest_dir() -> PathBuf {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memode_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f =
            std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(
            br#"{"artifacts": [
                {"name": "a", "file": "a.hlo.txt",
                 "inputs": [[6]], "outputs": [[10, 6]]}],
                "l96": {"dt": 0.02}}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn manifest_parses() {
        let dir = manifest_dir();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("a").unwrap();
        assert_eq!(a.inputs, vec![vec![6]]);
        assert_eq!(a.outputs, vec![vec![10, 6]]);
        assert_eq!(m.l96.get("dt").unwrap().as_f64(), Some(0.02));
        assert!(m.hlo_path("a").unwrap().ends_with("a.hlo.txt"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_artifact_lists_names() {
        let dir = manifest_dir();
        let m = ArtifactManifest::load(&dir).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("have: a"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = ArtifactManifest::load(Path::new("/nonexistent-xyz"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"));
    }
}
