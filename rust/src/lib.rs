//! # memode — continuous-time digital twins on an analogue memristive
//! # neural-ODE solver
//!
//! Reproduction of *"Continuous-Time Digital Twin with Analogue Memristive
//! Neural Ordinary Differential Equation Solver"* (Chen et al., 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the digital-twin coordinator (request
//!   routing, batching, scheduling, telemetry) plus a from-scratch
//!   behavioural simulation of the paper's analogue hardware: TaOx memristor
//!   devices, 1T1R crossbar arrays with differential-pair weight mapping,
//!   TIA / diode-ReLU / clamp peripheral circuits and the closed-loop IVP
//!   integrator that together solve a neural ODE entirely in the "analogue"
//!   domain.
//! * **Layer 2 (python/compile, build time)** — JAX definitions of the
//!   neural-ODE compute graphs, trained and AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the crossbar VMM and the fused RK4 step.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate, behind the non-default `pjrt` cargo feature) — this is the
//! *digital* execution backend the paper benchmarks against; the
//! [`analog`] + [`crossbar`] + [`device`] stack is the *analogue* backend
//! (the paper's contribution). [`twin`] exposes both behind one trait and
//! [`coordinator`] serves them.
//!
//! ## The batched request path
//!
//! Serving is batched end to end. The coordinator's dynamic batcher
//! coalesces same-route jobs; the scheduler hands each batch to a worker,
//! which executes it as **one `twin::Twin::run_batch` call** (requests with
//! differing `n_points` split into compatible sub-batches — never padded).
//! Underneath, the whole stack rolls B trajectories out in lockstep over a
//! flat row-major `[b * d]` state:
//!
//! * [`ode::batch::BatchVectorField`] is the batched field abstraction
//!   (serial [`ode::VectorField`]s auto-lift at B = 1); every solver has a
//!   `solve_batch` built on it;
//! * the digital models ([`models::mlp::Mlp`], resnet, rnn/gru/lstm) run
//!   one GEMM per layer per step for the whole batch;
//! * the analogue solver performs one **multi-vector crossbar read** per
//!   layer per circuit step ([`crossbar::vmm::VmmEngine::vmm_batch_into`]):
//!   one GEMM over the cached weights plus moment-matched per-row read
//!   noise, feeding B private integrator banks.
//!
//! Amortising weight traversal, variance computation, RNG and per-step
//! allocation across the batch is the single biggest throughput lever in
//! the system (`cargo bench --bench batch_throughput`); with noise off the
//! batched trajectories are bit-identical to serial runs — a contract
//! enforced by `rust/tests/batched.rs`.
//!
//! ## The twin zoo (generic core + scenario DSL)
//!
//! Every served dynamical system is one [`twin::core::DynamicsTwin`]: a
//! declarative [`twin::core::TwinSpec`] (name, state dimension, `dt`,
//! default initial state, seed root) bound to a
//! [`twin::core::CoreBackend`] (analogue crossbar — plain, sharded or
//! aging — digital RK4 on an MLP or closed-form field, recurrent,
//! resnet, PJRT). The request-execution machinery — request validation,
//! auto-seed stamping, ensemble lane expansion, group planning, pooled
//! trajectories, batched dispatch — lives **once** in the core, so the
//! cross-cutting invariants below are properties of the shared path,
//! not of any particular twin. `twin::hp` and `twin::lorenz96` are thin
//! configuration over the core (their public constructors are
//! unchanged); `twin::kuramoto` and `twin::l96two` show the marginal
//! cost of a new world: ~100 lines of [`ode::VectorField`] plus a
//! registry stanza ([`twin::registry::TwinRegistry::register_info`],
//! with [`twin::registry::RouteInfo`] powering route-table prints and
//! dimension-checked admission).
//!
//! Scenarios make rollouts declarative too: a `*.twin` file
//! ([`twin::scenario::Scenario`], format in `docs/SCENARIOS.md`) names a
//! route, horizon, seed, stimulus program, ensemble sweep and
//! expected-envelope assertions. Parse errors carry byte spans rendered
//! as compiler-style `--> file:line:col` diagnostics (pinned by
//! `rust/tests/scenarios.rs`); `memode scenario check` lints them,
//! `memode run-twin --scenario` executes them, and `loadgen
//! --scenarios` replays them as a request mix. The committed fixtures in
//! `examples/scenarios/` run end to end against the synthetic registry
//! in CI.
//!
//! ## Perf invariants (the zero-allocation hot path)
//!
//! Three structural invariants keep the steady-state request path off the
//! allocator and cache-friendly; new code on the hot path must preserve
//! them (they are enforced by `rust/tests/alloc.rs`, the bit-identity
//! suite in `rust/tests/batched.rs`, and the tracked benchmark
//! `BENCH_batch_throughput.json` written by
//! [`twin::throughput`] / `cargo bench --bench batch_throughput`):
//!
//! 1. **Flat trajectory layout.** Solver output is
//!    [`util::tensor::Trajectory`] — one contiguous row-major buffer, row
//!    = one sample (`dim = batch * d` for lockstep batched solves) — at
//!    every layer from `ode::{euler, rk4, dopri5}` through
//!    [`analog::system::AnalogNeuralOde`] and the twins to
//!    `twin::TwinResponse`. Nested `Vec<Vec<f64>>` is reserved for
//!    report/metric code (`Trajectory::to_nested`).
//! 2. **Accumulation-order contract.** The tiled batched GEMM
//!    (`util::tensor::Mat::vecmat_batch_into`) may reorder *memory
//!    traversal* freely (column-blocked microkernel, contiguous tiles)
//!    but must keep each output element's floating-point accumulation
//!    order over the shared dimension — including the zero-input skip —
//!    identical to the serial `vecmat_into`. That is what makes noise-off
//!    batched rollouts bit-identical to serial ones, and it is the
//!    invariant to re-verify before touching any kernel. The SIMD and
//!    multicore kernels (below) preserve this contract *by construction*
//!    rather than re-pinning it.
//!
//! ## Kernel dispatch (SIMD + multicore GEMM)
//!
//! Every `Mat::vecmat*` call — crossbar reads, model forwards, analogue
//! IVP steps — executes through the runtime-dispatched microkernels of
//! [`util::kernel`]:
//!
//! * **Runtime detection.** x86_64 with AVX2 runs the vectorised tile
//!   kernel (`is_x86_feature_detected!`, checked once and cached); every
//!   other target runs the portable scalar kernel. There is no
//!   compile-time feature requirement: one binary serves both.
//! * **Forced-scalar override.** `MEMODE_KERNEL=scalar` pins the scalar
//!   kernel process-wide (`simd` / `auto` analogously); the value is read
//!   once into a `OnceLock`, so the override costs the warm path nothing
//!   and the zero-allocation contract holds. Tests pin kernels through
//!   the explicit `Mat::*_with` entry points instead of the environment.
//! * **Threading threshold.** Batched GEMMs fan out over scoped threads
//!   in disjoint trajectory blocks only past `kernel::THREAD_MIN_BATCH`
//!   trajectories *and* `kernel::THREAD_MIN_WORK` multiply-adds (capped
//!   by `MEMODE_GEMM_THREADS`); below, they stay on the caller's thread.
//!   The threaded path allocates (thread spawn) and is deliberately
//!   outside invariant 3, exactly like the shard fan-out of
//!   `twin::shard` — the thresholds keep it off the warm zero-alloc
//!   request path, which `rust/tests/alloc.rs` enforces.
//! * **Surviving accumulation contract.** The AVX2 kernel vectorises
//!   across output *columns* with plain mul+add (never FMA, whose single
//!   rounding would diverge from scalar) and keeps the zero-input skip,
//!   so each output element's accumulation order over the shared
//!   dimension is exactly the serial order; the threaded path never
//!   splits a trajectory. Scalar, SIMD and threaded outputs are
//!   therefore bit-identical — enforced by kernel/tensor unit tests and
//!   the property suite (`rust/tests/properties.rs`) — and noise-lane
//!   draw indexing (invariant 2 of the noise rules below) is independent
//!   of kernel choice because noise is applied by index *after* the
//!   GEMM.
//! 3. **Scratch-arena ownership.** Every hot-path worker object owns its
//!    reusable scratch: solver steppers (`ode::rk4::Rk4`,
//!    `ode::euler::Euler`) their stage buffers; the analogue loop its
//!    integrator bank, stacked inputs and drive buffer; `VmmEngine` its
//!    batched noise scratch (reserved once per largest batch seen); the
//!    twins their group plans, staging vectors and pooled response
//!    trajectories (`util::tensor::TrajectoryPool`, refilled via
//!    `recycle`); the scheduler workers their request/result staging
//!    vectors (request *payload* clones still allocate at the dispatch
//!    shim — the zero-allocation contract is scoped to the twins'
//!    `run_batch_into`). Drive closures write into caller-provided slices
//!    (`FnMut(f64, &mut [f64])`) instead of returning fresh `Vec`s. A
//!    warm `Twin::run_batch_into` therefore performs **zero** heap
//!    allocations in steady state.
//!
//! ## Tile-sharded execution (states larger than one array)
//!
//! A 32x32 physical array bounds what one monolithic rollout can model;
//! real digital-twin states (Lorenz96 at d = 64/128) span several tiles.
//! Sharding makes that a first-class execution path:
//!
//! * **Shard layout.** [`crossbar::tiling::ShardPlan`] partitions each
//!   layer's output columns into contiguous tile column-groups (boundaries
//!   on `PHYSICAL_SIDE` multiples where possible, uniform shard count
//!   across layers — `crossbar::tiling::uniform_layer_plans`). The state
//!   partition is the last layer's plan, so shard `s` owns the integrators
//!   behind the columns it produces.
//! * **Accumulation-order contract (extends invariant 2).** The
//!   column-shard kernels (`util::tensor::Mat::vecmat_cols_into`,
//!   `vecmat_batch_cols_into`, wrapped by
//!   [`crossbar::vmm::VmmEngine::vmm_shard_into`] /
//!   `vmm_shard_batch_into` / `column_shard`) restrict *which columns* are
//!   produced but never reorder any output element's accumulation over the
//!   shared dimension. Noise-off sharded rollouts are therefore
//!   bit-identical to monolithic ones — serial, batched, and fanned-out —
//!   enforced by `rust/tests/sharded.rs`.
//! * **Two execution forms.**
//!   [`analog::system::AnalogNeuralOde::with_shards`] runs the shards
//!   *serially* inside the solver (per-shard reads sharing each step's
//!   assembled input, per-shard integrator banks) and stays inside the
//!   zero-allocation contract (invariant 3; enforced for the sharded path
//!   in `rust/tests/alloc.rs`). [`twin::shard::ShardedAnalogOde`] *fans
//!   out*: one scoped OS thread per shard, synchronised by a barrier at
//!   every exchange point (state assembly, then each hidden layer) of
//!   every circuit step, shard slices stitched into the pooled response
//!   trajectory afterwards. Barrier semantics: every shard executes the
//!   identical barrier sequence per circuit step — 2 waits for the state
//!   exchange plus 2 per hidden layer (publish under the buffer's mutex,
//!   wait, copy the full buffer out, wait) — so lockstep requires the
//!   uniform shard count the plans guarantee. The fan-out path allocates
//!   per rollout (thread spawn) and is deliberately outside invariant 3.
//! * **Serving.** Sharded twins sit behind ordinary routes
//!   (`lorenz96/analog-sharded`); the scheduler's dispatch contract is
//!   unchanged while shard workers report `shard_rollouts` / `shard_steps`
//!   into [`coordinator::telemetry::Telemetry`]. The tracked benchmark
//!   gains `l96d64/analog` vs `l96d64/analog-shard2` rows
//!   (sharded-vs-monolithic ns/trajectory-step), and CI gates
//!   `BENCH_batch_throughput.json` against the committed
//!   `BENCH_baseline.json` (`rust/src/bin/bench_gate.rs`).
//!
//! ## Noise determinism (per-trajectory noise lanes)
//!
//! The analogue solver's read noise is part of the *model* (the paper
//! embraces device stochasticity), so a production twin must make noisy
//! rollouts replayable — for debugging, validation against the physical
//! asset, and Monte-Carlo ensembles. Three rules make noise a pure
//! function of the request, never of the serving schedule:
//!
//! 1. **Lane derivation.** Every request resolves to a seed: explicit
//!    (`twin::TwinRequest::seed`), router-stamped (derived from the job
//!    id), or twin-auto-derived — and the seed actually used is echoed in
//!    `twin::TwinResponse::seed`. The trajectory's noise stream is
//!    `util::rng::NoiseLane::from_seed(seed)`: a splitmix64-keyed
//!    *counter* generator (16 bytes of plain state, pooled in twin
//!    scratch — the zero-allocation contract of invariant 3 holds).
//! 2. **Draw-index scheme.** Kernels address draws by explicit index
//!    instead of consuming a shared sequence. A `NoiseMode::Fast` read of
//!    a layer draws output column `j` at lane index
//!    `cursor + col_offset + j` and advances the cursor by the *full*
//!    layer width; `NoiseMode::PerCell` draws cell `(r, c)` at
//!    `cursor + r * full_cols + col_offset + c` and advances by
//!    `rows * full_cols` (`crossbar::vmm::VmmEngine::draws_per_read`).
//!    `col_offset`/`full_cols` locate a [`crossbar::vmm::VmmEngine::column_shard`]
//!    slice in the full layer, so batched GEMM kernels, serial shard
//!    loops and parallel shard workers (each advancing private lane
//!    copies) all consume **identical** draws to the serial monolithic
//!    path.
//! 3. **Replay semantics.** Same seed ⇒ same trajectory, bit for bit,
//!    regardless of batch size (B ∈ {1, 8, 32, ...}), batch composition
//!    or ordering, shard count, and serial vs parallel fan-out — and
//!    across twin instances of the same deployment. Enforced by
//!    `rust/tests/noisy_determinism.rs` (gated in release CI via
//!    `cargo test --release -- noisy_determinism`); the serve CLI prints
//!    `run-twin --seed` replay commands from the telemetry seed ring.
//!
//! Touching any noise path, re-verify rule 2 first: a kernel that draws
//! sequentially (or advances by the *visited* count instead of the full
//! logical count) silently re-couples noise to the execution schedule.
//!
//! ## Ensemble invariants (Monte-Carlo ensembles as first-class requests)
//!
//! The paper treats device noise as part of the model (Fig. 2k's
//! conductance-spread histograms; the Lorenz96 ensemble arguments for
//! chaotic extrapolation), so the serving layer exposes noise *ensembles*
//! as one request: [`twin::TwinRequest::ensemble`] carries an
//! [`twin::EnsembleSpec`] (member count, percentile envelope, optional
//! member trajectories) and the response carries pooled
//! [`twin::EnsembleStats`]. Three rules, built on the noise-determinism
//! invariants above:
//!
//! 1. **Lane derivation.** An ensemble request with family seed `s`
//!    expands into N lanes inside **one** batched rollout — member `k`
//!    runs on `NoiseLane::from_seed(ensemble_member_seed(s, k))`
//!    ([`twin::ensemble_member_seed`] = `derive_stream_seed(s, k)`). The
//!    key invariant: member `k` is bit-identical to a *standalone*
//!    rollout submitted with that derived seed, across batch size, batch
//!    composition, lane-capacity group splits and shard layout (serial
//!    in-solver sharding and the parallel fan-out) — enforced by
//!    `rust/tests/ensemble.rs`, release-gated in CI. There is no
//!    per-member dispatch anywhere: N lanes ride the existing
//!    `solve_batch_into` / sharded paths.
//! 2. **Lane-counted batching.** Capacity accounting everywhere counts
//!    *effective lanes* ([`twin::TwinRequest::lanes`]), not requests: the
//!    coordinator's batcher matures a batch when pending lanes reach
//!    `max_batch`, and the twins' `GroupPlan::plan_lanes` splits
//!    sub-batches at [`twin::MAX_SUB_BATCH_LANES`] so one rollout's flat
//!    state (and the solver scratch high-water marks behind it) stays
//!    bounded. The router validates specs (member cap, percentile range)
//!    before admission; [`coordinator::telemetry::Telemetry`] counts
//!    `ensemble_rollouts` / `ensemble_members`.
//! 3. **Pooled stats buffers (extends perf invariant 3).** Per-timestep
//!    mean/std come from a streaming Welford accumulator
//!    ([`util::stats::EnsembleAccumulator`]) whose output buffers are
//!    drawn from the twin's `TrajectoryPool`; percentile envelopes sort
//!    member values in reused scratch (`f64::total_cmp` — NaN samples
//!    from diverged members are skipped and counted, never a panic); the
//!    response's `trajectory` is a pooled copy of the ensemble mean; and
//!    `recycle` reclaims every stats trajectory plus the emptied
//!    [`twin::EnsembleStats`] shell. A warm ensemble batch therefore
//!    performs zero heap allocations (enforced by the ensemble case in
//!    `rust/tests/alloc.rs`).
//!
//! ## Device-lifetime invariants (aging, recalibration, degradation)
//!
//! The analogue crossbar is a *mortal* device: conductances drift and
//! diffuse with device age, cells get stuck, and reprogramming costs
//! write-verify pulses. [`analog::system::AnalogMlp::deploy_aging`] makes
//! that state explicit, and [`twin::health::MonitoredTwin`] runs the
//! detect → recalibrate → degrade loop over it. Four rules:
//!
//! 1. **Virtual clock only.** Device age advances exclusively through
//!    `advance_age(dt_s)` — per served rollout
//!    ([`twin::health::LifetimeConfig::age_per_rollout_s`]), per
//!    recalibration backoff, or explicitly in accelerated-aging
//!    experiments. Wall-clock time never touches device state, so every
//!    lifetime trajectory is replayable (`rust/tests/lifetime.rs`,
//!    release-gated in CI).
//! 2. **Aging never perturbs the read path.** `advance_age` mutates the
//!    *cached* engine conductances in place (drift factor + seeded
//!    diffusion from the deployment's own aging stream); reads, noise
//!    draw-index counts and `draws_per_read` are untouched. An un-aged
//!    `deploy_aging` twin is bit-identical to a plain `deploy` twin, and
//!    the zero-allocation + noise-determinism contracts above hold
//!    unchanged on the aged fast path.
//! 3. **Detect → recalibrate → degrade, never silent failure.** Every
//!    `probe_every` rollouts the monitor replays a fixed-seed probe on
//!    the analogue hardware and its golden digital reference and compares
//!    with the paper's MRE (Eq. 5). A threshold crossing triggers
//!    reprogramming (pulses charged as energy via
//!    [`energy::recalibration_energy`]) with bounded retries and
//!    exponential virtual backoff; exhausting
//!    [`twin::health::LifetimeConfig::max_recal_failures`] consecutive
//!    episodes flips the route to digital fallback with
//!    [`twin::TwinResponse::degraded`] stamped `true` — degraded service
//!    is always flagged, never silent, and
//!    [`coordinator::telemetry::Telemetry`] carries the per-route
//!    [`twin::health::LifetimeSnapshot`].
//! 4. **Fault campaigns are populations, replayable.** A
//!    [`twin::FaultCampaign`] on an ensemble request samples one fresh
//!    deployment per member (yield map from `derive_stream_seed(
//!    yield_seed, k)`, noise from `ensemble_member_seed(seed, k)`), so
//!    pooled stats describe a device population and replay bit-exactly
//!    from the (request seed, yield seed) pair.
//!
//! ## The network front door (TCP serving + wire protocol)
//!
//! [`coordinator::net`] puts the coordinator behind a socket: a single
//! poll-loop thread over non-blocking `std::net` (no async runtime —
//! the dependency budget is `anyhow` only) speaking the length-prefixed
//! JSON protocol of [`coordinator::wire`], specified byte-for-byte in
//! `docs/PROTOCOL.md` and operated per `docs/SERVING.md`. Rules:
//!
//! 1. **One admission discipline.** The server decodes a frame into the
//!    same `twin::TwinRequest` in-process callers build and submits it
//!    through the same `coordinator::service::Coordinator::try_submit`
//!    gates (global + per-route [`coordinator::backpressure`]); sheds
//!    surface as typed `rejected_overload` error frames and land in the
//!    same per-route shed counters. A connection cap guards the poll
//!    loop itself; past it, sockets get one `rejected_overload` frame
//!    and are closed. Nothing network-facing ever blocks the loop: all
//!    sockets are non-blocking, responses queue per-connection.
//! 2. **Seeds are stamped before admission.** The net layer assigns a
//!    seedless request its job-derived replay seed *before* the
//!    admission gates, so even a shed request's error frame echoes the
//!    seed that a retry can pin (`seed` field of the error envelope) —
//!    the replay contract of the noise rules above extends to
//!    rejections. Seeds ride the wire as decimal strings (u64 exceeds
//!    the f64 mantissa of JSON numbers).
//! 3. **Canonical encoding.** [`coordinator::wire`] encodes objects
//!    with sorted keys and deterministic number formatting, so protocol
//!    examples in the docs round-trip bit-exactly
//!    (`rust/tests/wire.rs`) and servers are byte-reproducible given
//!    the same responses.
//! 4. **Graceful drain.** Shutdown stops accepting, answers new frames
//!    with `shutting_down`, flushes queued responses within the drain
//!    budget, then joins — in-flight work is completed, never dropped
//!    silently. Socket-level coverage lives in `rust/tests/serve_net.rs`.
//!
//! `memode serve --listen HOST:PORT` binds it (`--synthetic` serves
//! fixture weights, no artifacts needed); `memode loadgen` (or the
//! standalone `loadgen` binary) drives it and reports p50/p99/p99.9
//! latency + rejected fraction into `BENCH_serve.json`.
//!
//! ## Scheduling invariants (throughput levers that cannot change bytes)
//!
//! Between admission and execution sit three throughput levers —
//! adaptive batch windows, work stealing, shard co-scheduling
//! (`docs/SERVING.md` documents the operator knobs). The rule that
//! makes them safe to flip on a live fleet:
//!
//! 1. **Responses are pure functions of seeded requests.** By the noise
//!    rules above, a request's response bytes depend only on the request
//!    (and its seed) — never on batch composition, dispatch target, or
//!    execution interleaving. Every scheduling lever exploits exactly
//!    this freedom and nothing else.
//! 2. **Adaptive windows only move time, not work.**
//!    [`coordinator::batcher`] sizes each route's coalescing window from
//!    the route's execution EWMA
//!    ([`coordinator::telemetry::Telemetry::record_route_exec`]),
//!    clamped to `[batch_window_min_s, batch_window_max_s]`; equal
//!    bounds (the default) reproduce the fixed window exactly. Windows
//!    change *when* a batch flushes and *what coalesces*, which by rule
//!    1 cannot change any response.
//! 3. **Stealing moves whole batches.** [`coordinator::scheduler`]
//!    workers own per-worker deques; an idle worker (with `steal` on)
//!    takes a complete queued batch from the most-loaded peer. A batch
//!    is never split, so it still executes as one `run_batch` on one
//!    worker's twin — relocation is invisible to the result.
//! 4. **Co-scheduling fuses execution, not state.**
//!    [`twin::shard::ShardedAnalogOde::solve_groups_into`] runs several
//!    trajectory groups under one fused barrier schedule, but each group
//!    keeps private integrator banks, noise lanes and exchange buffers,
//!    and the fused active-set schedule is a pure function of group
//!    shapes. Per-group operations execute in the same order on the
//!    same private state as the sequential path — bit-identity by
//!    construction.
//!
//! The cross-configuration contract (steal × co-schedule × submission
//! order, mixed plain/ensemble/sharded streams) is pinned by
//! `rust/tests/scheduling.rs`; the front-door fairness valve that keeps
//! greedy pipeliners from distorting these levers (round-robin frame
//! decoding + per-connection in-flight cap) by `rust/tests/serve_net.rs`.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

pub mod analog;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod device;
pub mod energy;
pub mod metrics;
pub mod models;
pub mod ode;
pub mod runtime;
pub mod twin;
pub mod util;
pub mod workload;

/// Crate version, reported by the CLI and the coordinator's health endpoint.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
