//! # memode — continuous-time digital twins on an analogue memristive
//! # neural-ODE solver
//!
//! Reproduction of *"Continuous-Time Digital Twin with Analogue Memristive
//! Neural Ordinary Differential Equation Solver"* (Chen et al., 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the digital-twin coordinator (request
//!   routing, batching, scheduling, telemetry) plus a from-scratch
//!   behavioural simulation of the paper's analogue hardware: TaOx memristor
//!   devices, 1T1R crossbar arrays with differential-pair weight mapping,
//!   TIA / diode-ReLU / clamp peripheral circuits and the closed-loop IVP
//!   integrator that together solve a neural ODE entirely in the "analogue"
//!   domain.
//! * **Layer 2 (python/compile, build time)** — JAX definitions of the
//!   neural-ODE compute graphs, trained and AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the crossbar VMM and the fused RK4 step.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate, behind the non-default `pjrt` cargo feature) — this is the
//! *digital* execution backend the paper benchmarks against; the
//! [`analog`] + [`crossbar`] + [`device`] stack is the *analogue* backend
//! (the paper's contribution). [`twin`] exposes both behind one trait and
//! [`coordinator`] serves them.
//!
//! ## The batched request path
//!
//! Serving is batched end to end. The coordinator's dynamic batcher
//! coalesces same-route jobs; the scheduler hands each batch to a worker,
//! which executes it as **one `twin::Twin::run_batch` call** (requests with
//! differing `n_points` split into compatible sub-batches — never padded).
//! Underneath, the whole stack rolls B trajectories out in lockstep over a
//! flat row-major `[b * d]` state:
//!
//! * [`ode::batch::BatchVectorField`] is the batched field abstraction
//!   (serial [`ode::VectorField`]s auto-lift at B = 1); every solver has a
//!   `solve_batch` built on it;
//! * the digital models ([`models::mlp::Mlp`], resnet, rnn/gru/lstm) run
//!   one GEMM per layer per step for the whole batch;
//! * the analogue solver performs one **multi-vector crossbar read** per
//!   layer per circuit step ([`crossbar::vmm::VmmEngine::vmm_batch_into`]):
//!   one GEMM over the cached weights plus moment-matched per-row read
//!   noise, feeding B private integrator banks.
//!
//! Amortising weight traversal, variance computation, RNG and per-step
//! allocation across the batch is the single biggest throughput lever in
//! the system (`cargo bench --bench batch_throughput`); with noise off the
//! batched trajectories are bit-identical to serial runs — a contract
//! enforced by `rust/tests/batched.rs`.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

pub mod analog;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod device;
pub mod energy;
pub mod metrics;
pub mod models;
pub mod ode;
pub mod runtime;
pub mod twin;
pub mod util;
pub mod workload;

/// Crate version, reported by the CLI and the coordinator's health endpoint.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
