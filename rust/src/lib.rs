//! # memode — continuous-time digital twins on an analogue memristive
//! # neural-ODE solver
//!
//! Reproduction of *"Continuous-Time Digital Twin with Analogue Memristive
//! Neural Ordinary Differential Equation Solver"* (Chen et al., 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the digital-twin coordinator (request
//!   routing, batching, scheduling, telemetry) plus a from-scratch
//!   behavioural simulation of the paper's analogue hardware: TaOx memristor
//!   devices, 1T1R crossbar arrays with differential-pair weight mapping,
//!   TIA / diode-ReLU / clamp peripheral circuits and the closed-loop IVP
//!   integrator that together solve a neural ODE entirely in the "analogue"
//!   domain.
//! * **Layer 2 (python/compile, build time)** — JAX definitions of the
//!   neural-ODE compute graphs, trained and AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the crossbar VMM and the fused RK4 step.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate, behind the non-default `pjrt` cargo feature) — this is the
//! *digital* execution backend the paper benchmarks against; the
//! [`analog`] + [`crossbar`] + [`device`] stack is the *analogue* backend
//! (the paper's contribution). [`twin`] exposes both behind one trait and
//! [`coordinator`] serves them.
//!
//! ## The batched request path
//!
//! Serving is batched end to end. The coordinator's dynamic batcher
//! coalesces same-route jobs; the scheduler hands each batch to a worker,
//! which executes it as **one `twin::Twin::run_batch` call** (requests with
//! differing `n_points` split into compatible sub-batches — never padded).
//! Underneath, the whole stack rolls B trajectories out in lockstep over a
//! flat row-major `[b * d]` state:
//!
//! * [`ode::batch::BatchVectorField`] is the batched field abstraction
//!   (serial [`ode::VectorField`]s auto-lift at B = 1); every solver has a
//!   `solve_batch` built on it;
//! * the digital models ([`models::mlp::Mlp`], resnet, rnn/gru/lstm) run
//!   one GEMM per layer per step for the whole batch;
//! * the analogue solver performs one **multi-vector crossbar read** per
//!   layer per circuit step ([`crossbar::vmm::VmmEngine::vmm_batch_into`]):
//!   one GEMM over the cached weights plus moment-matched per-row read
//!   noise, feeding B private integrator banks.
//!
//! Amortising weight traversal, variance computation, RNG and per-step
//! allocation across the batch is the single biggest throughput lever in
//! the system (`cargo bench --bench batch_throughput`); with noise off the
//! batched trajectories are bit-identical to serial runs — a contract
//! enforced by `rust/tests/batched.rs`.
//!
//! ## Perf invariants (the zero-allocation hot path)
//!
//! Three structural invariants keep the steady-state request path off the
//! allocator and cache-friendly; new code on the hot path must preserve
//! them (they are enforced by `rust/tests/alloc.rs`, the bit-identity
//! suite in `rust/tests/batched.rs`, and the tracked benchmark
//! `BENCH_batch_throughput.json` written by
//! [`twin::throughput`] / `cargo bench --bench batch_throughput`):
//!
//! 1. **Flat trajectory layout.** Solver output is
//!    [`util::tensor::Trajectory`] — one contiguous row-major buffer, row
//!    = one sample (`dim = batch * d` for lockstep batched solves) — at
//!    every layer from `ode::{euler, rk4, dopri5}` through
//!    [`analog::system::AnalogNeuralOde`] and the twins to
//!    `twin::TwinResponse`. Nested `Vec<Vec<f64>>` is reserved for
//!    report/metric code (`Trajectory::to_nested`).
//! 2. **Accumulation-order contract.** The tiled batched GEMM
//!    (`util::tensor::Mat::vecmat_batch_into`) may reorder *memory
//!    traversal* freely (column-blocked microkernel, contiguous tiles)
//!    but must keep each output element's floating-point accumulation
//!    order over the shared dimension — including the zero-input skip —
//!    identical to the serial `vecmat_into`. That is what makes noise-off
//!    batched rollouts bit-identical to serial ones, and it is the
//!    invariant to re-verify before touching any kernel.
//! 3. **Scratch-arena ownership.** Every hot-path worker object owns its
//!    reusable scratch: solver steppers (`ode::rk4::Rk4`,
//!    `ode::euler::Euler`) their stage buffers; the analogue loop its
//!    integrator bank, stacked inputs and drive buffer; `VmmEngine` its
//!    batched noise scratch (reserved once per largest batch seen); the
//!    twins their group plans, staging vectors and pooled response
//!    trajectories (`util::tensor::TrajectoryPool`, refilled via
//!    `recycle`); the scheduler workers their request/result staging
//!    vectors (request *payload* clones still allocate at the dispatch
//!    shim — the zero-allocation contract is scoped to the twins'
//!    `run_batch_into`). Drive closures write into caller-provided slices
//!    (`FnMut(f64, &mut [f64])`) instead of returning fresh `Vec`s. A
//!    warm `Twin::run_batch_into` therefore performs **zero** heap
//!    allocations in steady state.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

pub mod analog;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod device;
pub mod energy;
pub mod metrics;
pub mod models;
pub mod ode;
pub mod runtime;
pub mod twin;
pub mod util;
pub mod workload;

/// Crate version, reported by the CLI and the coordinator's health endpoint.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
