//! Fig. 4i + Supplementary Table 1: Lorenz96 energy per inference sample
//! across hidden sizes for the digital models vs the projected integrated
//! memristive solver.
//!
//! Paper anchors @512: energy ratios 189.7x (node), 147.2x (LSTM),
//! 100.6x (GRU), 37.1x (RNN).
//!
//! Run: `cargo bench --bench fig4i_energy`

use memode::energy::analogue::{self, AnalogParams};
use memode::energy::digital::{self, GpuParams, ModelKind};
use memode::energy::report;

fn main() {
    let hidden_sizes = [64usize, 128, 256, 512];
    let gpu = GpuParams::default();
    let ana = AnalogParams::integrated();

    let rows = report::comparison_table(&hidden_sizes, &gpu, &ana);
    report::print_rows(
        "Fig. 4i (projection): energy per inference sample",
        &rows,
    );
    println!(
        "(paper anchors @512: node 189.7x, LSTM 147.2x, GRU 100.6x, \
         RNN 37.1x vs ours)"
    );

    // Supplementary Table 1: full per-model speed + energy detail,
    // including a whole-trajectory (2400-sample) projection with the
    // sensor-ADC cost digital twins pay and the analogue system avoids.
    println!("\n== Supplementary Table 1: full-trajectory projection (2400 samples, d=6) ==");
    println!(
        "{:<24} {:>7} {:>12} {:>12} {:>12}",
        "model", "hidden", "t/traj", "E/traj", "E adc-part"
    );
    for &h in &hidden_sizes {
        for kind in [
            ModelKind::NeuralOde,
            ModelKind::Lstm,
            ModelKind::Gru,
            ModelKind::Rnn,
        ] {
            // Digital twins digitise d=6 sensor channels every sample.
            let c = digital::project_trajectory(kind, 6, h, 6, 2400, &gpu);
            let adc = 6.0 * 2400.0 * gpu.e_adc;
            println!(
                "{:<24} {:>7} {:>9.1} ms {:>9.1} mJ {:>9.1} µJ",
                kind.label(),
                h,
                c.t_step * 1e3,
                c.e_step * 1e3,
                adc * 1e6
            );
        }
        let ours = analogue::project_trajectory(3, h, 2400, &ana);
        println!(
            "{:<24} {:>7} {:>9.1} ms {:>9.1} mJ {:>12}",
            "memristive-node (ours)",
            h,
            ours.t_step * 1e3,
            ours.e_step * 1e3,
            "0 (analogue)"
        );
    }
}
