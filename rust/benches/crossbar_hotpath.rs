//! Hot-path microbenchmarks: the crossbar VMM engine and the closed-loop
//! analogue solver — the targets of the EXPERIMENTS.md §Perf iteration.
//!
//! Covers:
//! * VMM across sizes and noise modes (Off / Fast moment-matched /
//!   PerCell reference) — quantifies what the moment-matched path buys;
//! * full analogue MLP forward (deploy + eval);
//! * closed-loop solve throughput (circuit steps / s);
//! * PJRT single-step execute round-trip (if artifacts are built).
//!
//! Run: `cargo bench --bench crossbar_hotpath`

use memode::analog::system::{AnalogMlp, AnalogNeuralOde, AnalogNoise, LayerWeights};
use memode::config::SystemConfig;
use memode::crossbar::differential::DifferentialArray;
use memode::crossbar::vmm::{NoiseMode, VmmEngine};
use memode::device::noise::NoiseSource;
use memode::device::taox::DeviceConfig;
use memode::util::bench::{black_box, print_table, Bencher};
use memode::util::rng::{NoiseLane, Pcg64};
use memode::util::tensor::Mat;

fn main() {
    let bench = Bencher::default();
    let mut results = Vec::new();
    let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };

    // ---- VMM engine across sizes and noise modes -------------------------
    for &n in &[16usize, 32] {
        let mut rng = Pcg64::seeded(1);
        let w = Mat::from_fn(n, n, |r, c| {
            ((r * n + c) as f64 / (n * n) as f64) - 0.5
        });
        let arr = DifferentialArray::deploy(&w, &cfg, &mut rng);
        let v: Vec<f64> = (0..n).map(|k| (k as f64 / n as f64) - 0.4).collect();
        let mut y = vec![0.0; n];
        for (mode, label) in [
            (NoiseMode::Off, "off"),
            (NoiseMode::Fast, "fast"),
            (NoiseMode::PerCell, "percell"),
        ] {
            let mut eng =
                VmmEngine::new(&arr, NoiseSource::new(0.01), mode);
            let mut lane = NoiseLane::from_seed(2);
            results.push(bench.run(
                &format!("vmm {n}x{n} noise={label}"),
                || {
                    eng.vmm_into(black_box(&v), &mut y, &mut lane);
                    y[0]
                },
            ));
        }
    }

    // ---- Analogue MLP forward (the L96 64-hidden field) -------------------
    let mut rng = Pcg64::seeded(3);
    let dims = [(6usize, 64usize), (64, 64), (64, 6)];
    let layers: Vec<LayerWeights> = dims
        .iter()
        .map(|&(r, c)| {
            LayerWeights::new(
                &Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.2, 0.2)),
                &vec![0.0; c],
            )
        })
        .collect();
    let sys_cfg = SystemConfig::default();
    let mut amlp = AnalogMlp::deploy(
        &layers,
        &sys_cfg.device,
        AnalogNoise::hardware(),
        4,
    );
    let u = [0.5, -0.2, 0.1, 0.3, -0.4, 0.2];
    let mut out = vec![0.0; 6];
    let mut mlane = NoiseLane::from_seed(9);
    results.push(bench.run("analog-mlp fwd 6-64-64-6", || {
        amlp.eval_into(black_box(&u), &mut out, &mut mlane);
        out[0]
    }));

    // ---- Closed-loop solve (circuit steps / s) ----------------------------
    let mlp2 = AnalogMlp::deploy(
        &layers,
        &sys_cfg.device,
        AnalogNoise::hardware(),
        5,
    );
    let mut ode = AnalogNeuralOde::new(mlp2, 6, 0.001);
    let r = bench.run("closed-loop 100 samples x 20 substeps", || {
        ode.solve(black_box(&u), &mut |_t, _x: &mut [f64]| {}, 0.02, 100)
    });
    let steps_per_s = (100.0 * 20.0) / r.median.as_secs_f64();
    results.push(r);
    println!("closed-loop throughput: {steps_per_s:.0} circuit steps/s");

    // ---- PJRT round-trip (optional) ---------------------------------------
    if let Ok(svc) =
        memode::runtime::service::PjrtService::start(&sys_cfg.artifacts_dir)
    {
        let h = svc.handle();
        if h.preload(&["l96_step_b1", "l96_step_b32"]).is_ok() {
            use memode::runtime::TensorF32;
            let one = TensorF32::from_f64(vec![6], &u);
            results.push(bench.run("pjrt l96_step b=1", || {
                h.execute("l96_step_b1", vec![one.clone()]).unwrap().data[0]
            }));
            let batch = TensorF32::from_f64(
                vec![32, 6],
                &(0..192).map(|k| (k % 7) as f64 * 0.1).collect::<Vec<_>>(),
            );
            results.push(bench.run("pjrt l96_step b=32", || {
                h.execute("l96_step_b32", vec![batch.clone()]).unwrap().data
                    [0]
            }));
        }
    } else {
        println!("(pjrt section skipped: artifacts not built)");
    }

    print_table("crossbar hot path", &results);
}
