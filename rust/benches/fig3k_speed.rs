//! Fig. 3k: HP-twin speed scaling — projected memristive solver vs the
//! neural ODE on digital hardware, across hidden sizes {8, 16, 32, 64}.
//!
//! Two sections:
//! 1. the paper-comparable *projection* (the analytic latency models of
//!    `energy::{digital, analogue}`, anchored at the paper's 4.2x @64);
//! 2. *measured* wall-clock of this repo's own executables per field
//!    evaluation: Rust-digital MLP vs the analogue circuit simulator
//!    (simulator time, NOT hardware time — labelled as such).
//!
//! Run: `cargo bench --bench fig3k_speed`

use memode::analog::system::{AnalogMlp, AnalogNoise, LayerWeights};
use memode::config::SystemConfig;
use memode::energy::analogue::{self, AnalogParams};
use memode::energy::digital::{GpuParams, ModelKind};
use memode::models::mlp::Mlp;
use memode::util::bench::{black_box, Bencher};
use memode::util::rng::{NoiseLane, Pcg64};
use memode::util::tensor::Mat;

fn field_layers(hidden: usize) -> Vec<(Mat, Vec<f64>)> {
    let mut rng = Pcg64::seeded(7);
    let dims = [(2, hidden), (hidden, hidden), (hidden, 1)];
    dims.iter()
        .map(|&(r, c)| {
            (
                Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.5, 0.5)),
                vec![0.0; c],
            )
        })
        .collect()
}

fn main() {
    let hidden_sizes = [8usize, 16, 32, 64];
    let gpu = GpuParams::default();
    let ana = AnalogParams::board();

    println!("== Fig. 3k (projection): HP field-eval latency vs hidden size ==");
    println!(
        "{:>8} {:>16} {:>16} {:>10}",
        "hidden", "digital node", "memristive", "speedup"
    );
    for &h in &hidden_sizes {
        // One field evaluation: 5 sequential kernels on GPU (paper's
        // Fig. 3k comparator), one settle chain on the analogue system.
        let dig = 5.0 * gpu.t_kernel_floor
            + ModelKind::RecurrentResNet.macs_per_step(2, h) / gpu.macs_per_s;
        let ours = analogue::project_step(3, h, &ana).t_step;
        println!(
            "{:>8} {:>13.1} µs {:>13.1} µs {:>9.2}x",
            h,
            dig * 1e6,
            ours * 1e6,
            dig / ours
        );
    }
    println!("(paper anchor: 4.2x at hidden 64)");

    println!("\n== Measured (this repo's simulators, per field eval) ==");
    let bench = Bencher::default();
    let cfg = SystemConfig::default();
    let mut results = Vec::new();
    for &h in &hidden_sizes {
        let layers = field_layers(h);
        // Digital: Rust MLP forward.
        let lw: Vec<LayerWeights> =
            layers.iter().map(|(w, b)| LayerWeights::new(w, b)).collect();
        let mut mlp = Mlp::new(layers.clone());
        let mut out = vec![0.0; 1];
        results.push(bench.run(&format!("digital-mlp fwd h={h}"), || {
            mlp.forward_into(black_box(&[0.5, 0.2]), &mut out);
            out[0]
        }));
        // Analogue simulator: deployed arrays + noisy reads.
        let mut amlp = AnalogMlp::deploy(
            &lw,
            &cfg.device,
            AnalogNoise::hardware(),
            11,
        );
        let mut aout = vec![0.0; 1];
        let mut lane = NoiseLane::from_seed(11);
        results.push(bench.run(&format!("analog-sim fwd h={h}"), || {
            amlp.eval_into(black_box(&[0.5, 0.2]), &mut aout, &mut lane);
            aout[0]
        }));
    }
    memode::util::bench::print_table("fig3k measured", &results);
}
