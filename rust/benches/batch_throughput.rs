//! Serial vs batched rollout throughput — the headline number of the
//! batched execution engine, emitted as the tracked benchmark
//! `BENCH_batch_throughput.json` (ns per trajectory-step, serial vs
//! batched, B ∈ {1, 8, 32, 128}, HP and Lorenz96 routes on the analogue
//! and digital backends, plus the wide d = 64 Lorenz96 pair tracking
//! sharded-vs-monolithic execution — compare the `l96d64/analog` and
//! `l96d64/analog-shard2` rows at equal B).
//!
//! Before timing, asserts the batched output is bit-identical to serial
//! under `NoiseMode::Off` — and the tile-sharded d = 64 route bit-identical
//! to the monolithic one — speed never buys accuracy drift.
//!
//! CI compares the smoke JSON against the committed `BENCH_baseline.json`
//! via `cargo run --release --bin bench_gate` (≤ 25% per-route regression
//! after machine-speed normalisation).
//!
//! Run: `cargo bench --bench batch_throughput [-- --smoke]`
//!
//! `--smoke` (or `BENCH_SMOKE=1`) is the CI quick-bench mode: fewer
//! iterations, shorter rollouts, B ∈ {1, 8, 32} — same JSON schema. The
//! tier-1 test suite also writes the smoke document
//! (`rust/tests/bench_smoke.rs`), so the JSON exists after any full test
//! run; running this bench overwrites it with higher-fidelity numbers.

use std::time::Duration;

use memode::twin::throughput::{
    assert_bit_identical, assert_sharded_matches_monolithic,
    default_json_path, measure, write_json,
};
use memode::util::bench::Bencher;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let (batch_sizes, n_points, bench): (&[usize], usize, Bencher) = if smoke
    {
        (
            &[1, 8, 32],
            12,
            Bencher {
                min_iters: 3,
                target_time: Duration::from_millis(60),
                warmup: Duration::from_millis(15),
            },
        )
    } else {
        (&[1, 8, 32, 128], 40, Bencher::quick())
    };

    // Correctness gate first: noise-off batched == serial, bit for bit,
    // and the tile-sharded wide route == the monolithic one.
    assert_bit_identical("hp/analog", 8, n_points);
    assert_bit_identical("hp/digital", 8, n_points);
    assert_bit_identical("l96/analog", 8, n_points);
    assert_bit_identical("l96/digital", 8, n_points);
    assert_bit_identical("l96d64/analog", 4, n_points);
    assert_bit_identical("l96d64/analog-shard2", 4, n_points);
    assert_sharded_matches_monolithic(4, n_points);
    println!(
        "bit-identity check (NoiseMode::Off, incl. sharded-vs-monolithic): \
         OK"
    );

    let entries = measure(batch_sizes, n_points, &bench);
    println!(
        "\n{:<14} {:>5} {:>16} {:>16} {:>9}",
        "route", "B", "serial ns/step", "batched ns/step", "speedup"
    );
    for e in &entries {
        println!(
            "{:<14} {:>5} {:>16.1} {:>16.1} {:>8.2}x",
            e.route,
            e.batch,
            e.serial_ns_per_step,
            e.batched_ns_per_step,
            e.speedup
        );
        if e.route == "hp/analog" && e.batch == 32 {
            // Acceptance: >= 1.5x per trajectory-step at B=32 on the HP
            // analogue route.
            println!(
                "acceptance (hp/analog B=32 >= 1.5x): {}",
                if e.speedup >= 1.5 { "PASS" } else { "FAIL" }
            );
        }
    }

    // Sharded-vs-monolithic summary (the tracked sharding comparison).
    let cell = |route: &str, b: usize| {
        entries.iter().find(|e| e.route == route && e.batch == b)
    };
    if let (Some(m), Some(s)) =
        (cell("l96d64/analog", 32), cell("l96d64/analog-shard2", 32))
    {
        println!(
            "\nsharded-vs-monolithic (l96d64, B=32, batched): {:.1} vs \
             {:.1} ns/step (mono/sharded {:.2}x)",
            s.batched_ns_per_step,
            m.batched_ns_per_step,
            m.batched_ns_per_step / s.batched_ns_per_step.max(1e-9)
        );
    }

    let path = default_json_path();
    write_json(&path, if smoke { "smoke" } else { "full" }, &entries)
        .expect("write benchmark json");
    println!(
        "\nwrote {} ({} entries, mode {})",
        path.display(),
        entries.len(),
        if smoke { "smoke" } else { "full" }
    );
}
