//! Serial vs batched rollout throughput — the headline number of the
//! batched execution engine.
//!
//! For B in {1, 8, 32}, times B serial `Twin::run` calls against one
//! `Twin::run_batch` call on the same twin, for the Lorenz96 twin on the
//! Analog (hardware noise point) and Digital backends. Before timing,
//! asserts the batched output is bit-identical to serial under
//! `NoiseMode::Off` — speed never buys accuracy drift.
//!
//! The analogue batched path amortises, per circuit step: the weight-matrix
//! traversal (one GEMM for the whole batch), the moment-matched variance
//! computation (a contiguous GEMM instead of B strided column walks), and
//! the per-step allocations of the serial drive path.
//!
//! Run: `cargo bench --bench batch_throughput`

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::MlpWeights;
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::{Twin, TwinRequest};
use memode::util::bench::{black_box, fmt_dur, print_table, Bencher};
use memode::util::rng::Pcg64;
use memode::util::tensor::Mat;

/// Trained-shape Lorenz96 field: 6 -> 64 -> 64 -> 6 with pseudo-random
/// weights (the timing-relevant structure of the real l96_node artifact).
fn l96_weights() -> MlpWeights {
    let mut rng = Pcg64::seeded(42);
    let dims = [(6usize, 64usize), (64, 64), (64, 6)];
    let layers = dims
        .iter()
        .map(|&(r, c)| {
            (
                Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.2, 0.2)),
                (0..c).map(|_| rng.uniform_in(-0.05, 0.05)).collect(),
            )
        })
        .collect();
    MlpWeights { layers, dt: 0.02, kind: "node".into(), task: "l96".into() }
}

fn requests(b: usize, n_points: usize) -> Vec<TwinRequest> {
    let mut rng = Pcg64::seeded(7);
    (0..b)
        .map(|_| {
            TwinRequest::autonomous(
                (0..6).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                n_points,
            )
        })
        .collect()
}

fn assert_bit_identical(twin: &mut dyn Twin, reqs: &[TwinRequest]) {
    let serial: Vec<_> =
        reqs.iter().map(|r| twin.run(r).unwrap()).collect();
    let batched = twin.run_batch(reqs);
    for (b, s) in batched.iter().zip(&serial) {
        assert_eq!(
            b.as_ref().unwrap().trajectory,
            s.trajectory,
            "batched != serial under noise-off"
        );
    }
}

fn main() {
    let device = DeviceConfig { fault_rate: 0.0, ..Default::default() };
    let quiet = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    };
    let w = l96_weights();
    let n_points = 40;

    // Correctness gate first: noise-off batched == serial, bit for bit.
    {
        let mut twin =
            Lorenz96Twin::analog(&w, &quiet, AnalogNoise::off(), 1);
        assert_bit_identical(&mut twin, &requests(8, n_points));
        let mut twin = Lorenz96Twin::digital(&w);
        assert_bit_identical(&mut twin, &requests(8, n_points));
        println!("bit-identity check (NoiseMode::Off): OK");
    }

    let bench = Bencher::quick();
    let mut results = Vec::new();

    for (label, mut twin) in [
        (
            "l96/analog",
            Lorenz96Twin::analog(&w, &device, AnalogNoise::hardware(), 1),
        ),
        ("l96/digital", Lorenz96Twin::digital(&w)),
    ] {
        for &b in &[1usize, 8, 32] {
            let reqs = requests(b, n_points);
            let serial = bench.run(&format!("{label} serial x{b}"), || {
                let mut n_ok = 0;
                for r in black_box(&reqs) {
                    n_ok += twin.run(r).unwrap().trajectory.len();
                }
                n_ok
            });
            let batched =
                bench.run(&format!("{label} run_batch B={b}"), || {
                    twin.run_batch(black_box(&reqs)).len()
                });
            let speedup = serial.median.as_secs_f64()
                / batched.median.as_secs_f64().max(1e-12);
            println!(
                "{label} B={b}: serial {} vs batched {} -> {speedup:.2}x",
                fmt_dur(serial.median),
                fmt_dur(batched.median),
            );
            if label == "l96/analog" && b == 32 {
                // Acceptance: >= 4x at B=32 on the analogue twin.
                println!(
                    "acceptance (l96/analog B=32 >= 4x): {}",
                    if speedup >= 4.0 { "PASS" } else { "FAIL" }
                );
            }
            results.push(serial);
            results.push(batched);
        }
    }

    print_table("serial vs batched rollout", &results);
}
