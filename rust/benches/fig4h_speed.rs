//! Fig. 4h: Lorenz96 execution time per inference sample across hidden
//! sizes {64, 128, 256, 512} for neural ODE / LSTM / GRU / RNN on digital
//! hardware vs the (projected integrated) memristive solver.
//!
//! Paper anchors @512: node 505.8 µs, LSTM 392.5, GRU 294.9, RNN 98.8,
//! ours 40.1 µs (12.6x / 9.8x / 7.4x / 2.5x).
//!
//! Also measures this repo's Rust-native per-step wall-clock for the same
//! architectures (simulator time, labelled as such).
//!
//! Run: `cargo bench --bench fig4h_speed`

use memode::energy::analogue::AnalogParams;
use memode::energy::digital::GpuParams;
use memode::energy::report;
use memode::models::gru::Gru;
use memode::models::loader::RnnWeights;
use memode::models::lstm::Lstm;
use memode::models::mlp::{Mlp, MlpField};
use memode::models::rnn::{Recurrent, VanillaRnn};
use memode::ode::rk4::Rk4;
use memode::ode::VectorField;
use memode::util::bench::{black_box, Bencher};
use memode::util::rng::Pcg64;
use memode::util::tensor::Mat;

fn rnn_weights(kind: &str, hidden: usize, gates: usize) -> RnnWeights {
    let d = 6;
    let mut rng = Pcg64::seeded(13);
    let mut m = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.1, 0.1))
    };
    RnnWeights {
        wx: m(d, gates * hidden),
        wh: m(hidden, gates * hidden),
        b: vec![0.0; gates * hidden],
        wo: m(hidden, d),
        bo: vec![0.0; d],
        hidden,
        d_in: d,
        dt: 0.02,
        kind: kind.into(),
    }
}

fn node_mlp(hidden: usize) -> Mlp {
    let mut rng = Pcg64::seeded(17);
    let dims = [(6, hidden), (hidden, hidden), (hidden, 6)];
    Mlp::new(
        dims.iter()
            .map(|&(r, c)| {
                (
                    Mat::from_fn(r, c, |_, _| rng.uniform_in(-0.1, 0.1)),
                    vec![0.0; c],
                )
            })
            .collect(),
    )
}

fn main() {
    let hidden_sizes = [64usize, 128, 256, 512];
    let rows = report::comparison_table(
        &hidden_sizes,
        &GpuParams::default(),
        &AnalogParams::integrated(),
    );
    report::print_rows(
        "Fig. 4h (projection): latency per inference sample",
        &rows,
    );
    println!(
        "(paper anchors @512: node 505.8 µs 12.6x, LSTM 392.5 9.8x, \
         GRU 294.9 7.4x, RNN 98.8 2.5x, ours 40.1 µs)"
    );

    println!("\n== Measured (Rust-native per step, simulator time) ==");
    let bench = Bencher::default();
    let mut results = Vec::new();
    let x0 = [0.5, -0.2, 0.1, 0.3, -0.4, 0.2];
    for &h in &hidden_sizes {
        // Neural ODE: one RK4 step.
        let mut mlp = node_mlp(h);
        let mut field = MlpField { mlp: &mut mlp, label: "fig4h" };
        let mut stepper = Rk4::new(field.dim());
        let mut state = x0.to_vec();
        results.push(bench.run(&format!("node rk4-step h={h}"), || {
            stepper.step(&mut field, 0.0, black_box(&mut state), 0.02);
            state[0]
        }));
        // Recurrent cells.
        let mut lstm = Lstm::new(rnn_weights("lstm", h, 4));
        results.push(bench.run(&format!("lstm step h={h}"), || {
            black_box(lstm.step(&x0))
        }));
        let mut gru = Gru::new(rnn_weights("gru", h, 3));
        results.push(bench.run(&format!("gru step h={h}"), || {
            black_box(gru.step(&x0))
        }));
        let mut rnn = VanillaRnn::new(rnn_weights("rnn", h, 1));
        results.push(bench.run(&format!("rnn step h={h}"), || {
            black_box(rnn.step(&x0))
        }));
    }
    memode::util::bench::print_table("fig4h measured", &results);
}
