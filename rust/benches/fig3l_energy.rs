//! Fig. 3l: HP-twin energy per forward pass — recurrent ResNet and neural
//! ODE on digital hardware vs the memristive system (experimental-board
//! power preset), across hidden sizes {8, 16, 32, 64}.
//!
//! Paper anchors at hidden 64: ResNet 176.4 µJ, node 705.4 µJ, ours
//! ~17.0 µJ (10.4x / 41.4x).
//!
//! Run: `cargo bench --bench fig3l_energy`

use memode::energy::analogue::{self, AnalogParams};
use memode::energy::digital::{self, GpuParams, ModelKind};

fn main() {
    let hidden_sizes = [8usize, 16, 32, 64];
    let gpu = GpuParams::default();
    let ana = AnalogParams::board();

    println!("== Fig. 3l (projection): energy per forward pass ==");
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>9} {:>9}",
        "hidden", "resnet", "node", "ours", "x resnet", "x node"
    );
    for &h in &hidden_sizes {
        // HP twin: d_state 1, stimulus 1 -> field input dim 2.
        let resnet =
            digital::project_step(ModelKind::RecurrentResNet, 2, h, 1, &gpu);
        let node = digital::project_step(ModelKind::NeuralOde, 2, h, 1, &gpu);
        let ours = analogue::project_step(3, h, &ana);
        println!(
            "{:>8} {:>11.1} µJ {:>11.1} µJ {:>11.1} µJ {:>8.1}x {:>8.1}x",
            h,
            resnet.e_step * 1e6,
            node.e_step * 1e6,
            ours.e_step * 1e6,
            resnet.e_step / ours.e_step,
            node.e_step / ours.e_step
        );
    }
    println!(
        "(paper anchors @64: resnet 176.4 µJ (10.4x), node 705.4 µJ (41.4x), \
         ours ~17 µJ)"
    );

    // Physics cross-check: static power of the actual deployed HP arrays.
    use memode::config::SystemConfig;
    use memode::crossbar::differential::DifferentialArray;
    use memode::twin::setup::TrainedWeights;
    use memode::util::rng::Pcg64;
    let cfg = SystemConfig::default();
    if let Ok(w) = TrainedWeights::load(&cfg) {
        let mut rng = Pcg64::seeded(3);
        let arrays: Vec<DifferentialArray> = w
            .hp_node
            .layers
            .iter()
            .map(|(wm, _)| {
                DifferentialArray::deploy(wm, &cfg.device, &mut rng)
            })
            .collect();
        let refs: Vec<&DifferentialArray> = arrays.iter().collect();
        let p_arrays = analogue::power_from_arrays(&refs, 0.2);
        println!(
            "\nphysics cross-check: deployed HP arrays draw {:.1} µW static \
             at 0.2 V RMS\n(middle of the road for the {:.0} mW board budget \
             — op-amps dominate, as on the paper's PCB)",
            p_arrays * 1e6,
            ana.power_w * 1e3
        );
    }
}
