//! GEMM microkernel sweep: scalar vs SIMD vs SIMD+threads on the batched
//! vector-matrix product that backs every crossbar read, model forward and
//! analogue IVP step (`Mat::vecmat_batch_into`).
//!
//! Sweeps (rows, cols) × batch × kernel variant and writes machine-readable
//! rows to `BENCH_gemm_kernels.json` at the repository root (override with
//! `BENCH_GEMM_OUT`). The JSON is a machine-local CI artifact like
//! `BENCH_batch_throughput.json` — uploaded, not committed.
//!
//! Before timing anything it asserts the SIMD and threaded variants are
//! bit-identical to scalar on every swept shape (the lib.rs accumulation
//! contract, checked here on the exact buffers about to be timed).
//!
//! The dense-vs-half-zero pair on the (64, 64) shape tracks the zero-input
//! skip (`if xv == 0.0 { continue; }`): the skip is contractual (it shields
//! non-finite weights behind zero inputs), and this pair measures what it
//! costs on dense inputs — historically ~free, one predicted branch per row.
//!
//! Run: `cargo bench --bench gemm_kernels [-- --smoke]`
//! (`--smoke` / `BENCH_SMOKE=1` = CI quick mode: fewer iters, fewer batches.)

use std::time::Duration;

use memode::util::bench::{black_box, Bencher, BenchResult};
use memode::util::json::{self, Json};
use memode::util::kernel::{self, KernelKind};
use memode::util::tensor::Mat;

/// Deterministic fill — xorshift so runs are comparable across machines.
fn fill(seed: u64, buf: &mut [f64]) {
    let mut s = seed | 1;
    for v in buf.iter_mut() {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        // Map to roughly [-1, 1); never exactly zero, so the zero-skip
        // branch stays cold on "dense" inputs.
        *v = (s >> 11) as f64 / (1u64 << 52) as f64 - 1.0 + 1e-9;
    }
}

struct Row {
    rows: usize,
    cols: usize,
    batch: usize,
    variant: &'static str,
    ns_per_call: f64,
    ns_per_madd: f64,
}

fn push_row(
    rows_out: &mut Vec<Row>,
    results: &mut Vec<BenchResult>,
    r: BenchResult,
    rows: usize,
    cols: usize,
    batch: usize,
    variant: &'static str,
) {
    let ns_per_call = r.median.as_secs_f64() * 1e9;
    let ns_per_madd = ns_per_call / (batch * rows * cols).max(1) as f64;
    rows_out.push(Row { rows, cols, batch, variant, ns_per_call, ns_per_madd });
    results.push(r);
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let (batches, bench): (&[usize], Bencher) = if smoke {
        (
            &[1, 32, 256],
            Bencher {
                min_iters: 3,
                target_time: Duration::from_millis(40),
                warmup: Duration::from_millis(10),
            },
        )
    } else {
        (&[1, 8, 32, 128, 512], Bencher::quick())
    };
    let shapes: &[(usize, usize)] =
        &[(14, 14), (64, 64), (64, 128), (128, 128)];

    let simd = kernel::detected();
    println!(
        "kernel detection: avx2 {}, active kind {:?}",
        if kernel::simd_available() { "yes" } else { "no" },
        kernel::active()
    );

    let mut rows_out: Vec<Row> = Vec::new();
    let mut results: Vec<BenchResult> = Vec::new();

    for &(rows, cols) in shapes {
        let mut w = Mat::zeros(rows, cols);
        fill(0x9E37_79B9 ^ (rows * 1000 + cols) as u64, &mut w.data);
        let max_b = *batches.iter().max().unwrap();
        let mut xs = vec![0.0f64; max_b * rows];
        fill(0xA5A5_5A5A ^ rows as u64, &mut xs);

        // Bit-identity gate on the exact buffers about to be timed: SIMD
        // and the threaded split must match scalar bit for bit.
        {
            let b = max_b.min(64);
            let mut y_sc = vec![0.0f64; b * cols];
            let mut y_simd = vec![0.0f64; b * cols];
            let mut y_mt = vec![0.0f64; b * cols];
            w.vecmat_batch_into_with(
                KernelKind::Scalar,
                1,
                &xs[..b * rows],
                b,
                &mut y_sc,
            );
            w.vecmat_batch_into_with(simd, 1, &xs[..b * rows], b, &mut y_simd);
            w.vecmat_batch_into_with(simd, 4, &xs[..b * rows], b, &mut y_mt);
            assert_eq!(
                y_sc.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "SIMD not bit-identical to scalar on {rows}x{cols}"
            );
            assert_eq!(
                y_sc.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_mt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threaded split not bit-identical on {rows}x{cols}"
            );
        }

        for &b in batches {
            let mut ys = vec![0.0f64; b * cols];
            let name = |variant: &str| {
                format!("{rows}x{cols} B={b} {variant}")
            };
            let r = bench.run(&name("scalar"), || {
                w.vecmat_batch_into_with(
                    KernelKind::Scalar,
                    1,
                    black_box(&xs[..b * rows]),
                    b,
                    &mut ys,
                );
                black_box(ys[0])
            });
            push_row(&mut rows_out, &mut results, r, rows, cols, b, "scalar");
            let r = bench.run(&name("simd"), || {
                w.vecmat_batch_into_with(
                    simd,
                    1,
                    black_box(&xs[..b * rows]),
                    b,
                    &mut ys,
                );
                black_box(ys[0])
            });
            push_row(&mut rows_out, &mut results, r, rows, cols, b, "simd");
            let r = bench.run(&name("simd+mt4"), || {
                w.vecmat_batch_into_with(
                    simd,
                    4,
                    black_box(&xs[..b * rows]),
                    b,
                    &mut ys,
                );
                black_box(ys[0])
            });
            push_row(&mut rows_out, &mut results, r, rows, cols, b, "simd+mt4");
        }
    }

    // Zero-skip satellite: dense vs half-zero inputs on (64, 64), both
    // kernels. The skip must stay ~free on dense inputs and win on sparse.
    {
        let (rows, cols) = (64usize, 64usize);
        let b = *batches.iter().max().unwrap();
        let mut w = Mat::zeros(rows, cols);
        fill(0xDEAD_BEEF, &mut w.data);
        let mut dense = vec![0.0f64; b * rows];
        fill(0x1234_5678, &mut dense);
        let mut half = dense.clone();
        for v in half.iter_mut().skip(1).step_by(2) {
            *v = 0.0;
        }
        let mut ys = vec![0.0f64; b * cols];
        for (variant, kind) in
            [("scalar", KernelKind::Scalar), ("simd", simd)]
        {
            for (input, xsrc) in [("dense", &dense), ("halfzero", &half)] {
                let r = bench.run(
                    &format!("zeroskip {variant} {input} B={b}"),
                    || {
                        w.vecmat_batch_into_with(
                            kind,
                            1,
                            black_box(&xsrc[..]),
                            b,
                            &mut ys,
                        );
                        black_box(ys[0])
                    },
                );
                let variant_name: &'static str = match (variant, input) {
                    ("scalar", "dense") => "zeroskip/scalar/dense",
                    ("scalar", "halfzero") => "zeroskip/scalar/halfzero",
                    ("simd", "dense") => "zeroskip/simd/dense",
                    _ => "zeroskip/simd/halfzero",
                };
                push_row(
                    &mut rows_out,
                    &mut results,
                    r,
                    rows,
                    cols,
                    b,
                    variant_name,
                );
            }
        }
    }

    memode::util::bench::print_table("GEMM kernel sweep", &results);

    let json_rows: Vec<Json> = rows_out
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("rows", Json::Num(r.rows as f64)),
                ("cols", Json::Num(r.cols as f64)),
                ("batch", Json::Num(r.batch as f64)),
                ("variant", Json::Str(r.variant.to_string())),
                ("ns_per_call", Json::Num(r.ns_per_call)),
                ("ns_per_madd", Json::Num(r.ns_per_madd)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("gemm_kernels".into())),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("simd_available", Json::Bool(kernel::simd_available())),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = std::env::var("BENCH_GEMM_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("../BENCH_gemm_kernels.json")
        });
    json::to_file(&path, &doc).expect("write gemm kernel json");
    println!(
        "\nwrote {} ({} rows, mode {})",
        path.display(),
        rows_out.len(),
        if smoke { "smoke" } else { "full" }
    );
}
