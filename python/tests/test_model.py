"""L2 model graphs: rollout shapes, pallas/ref agreement, solver accuracy."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model


def test_init_params_shapes():
    params = model.init_params(model.HP_LAYERS, jax.random.PRNGKey(0))
    shapes = [(w.shape, b.shape) for w, b in params]
    assert shapes == [((2, 14), (14,)), ((14, 14), (14,)), ((14, 1), (1,))]


def test_params_pytree_roundtrip():
    params = model.init_params((3, 5, 2), jax.random.PRNGKey(1))
    tree = model.params_to_pytree(params)
    back = model.pytree_to_params(tree)
    for (w1, b1), (w2, b2) in zip(params, back):
        np.testing.assert_array_equal(w1, w2)
        np.testing.assert_array_equal(b1, b2)


def test_rollout_autonomous_shapes_and_pallas_parity():
    key = jax.random.PRNGKey(2)
    params = model.init_params((6, 16, 16, 6), key)
    h0 = jax.random.normal(key, (6,))
    a = model.rollout_autonomous(params, h0, 20, 0.02, use_pallas=True)
    b = model.rollout_autonomous(params, h0, 20, 0.02, use_pallas=False)
    assert a.shape == (21, 6)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_rollout_driven_shapes_and_pallas_parity():
    key = jax.random.PRNGKey(3)
    params = model.init_params(model.HP_LAYERS, key)
    n_steps = 25
    xs_half = jax.random.normal(key, (2 * n_steps + 1, 1)) * 0.5
    h0 = jnp.array([0.3], jnp.float32)
    a = model.rollout_driven(params, h0, xs_half, 1e-3, use_pallas=True)
    b = model.rollout_driven(params, h0, xs_half, 1e-3, use_pallas=False)
    assert a.shape == (n_steps + 1, 1)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_rollout_first_row_is_h0():
    params = model.init_params((4, 8, 4), jax.random.PRNGKey(4))
    h0 = jnp.array([1.0, -1.0, 0.5, 0.0], jnp.float32)
    traj = model.rollout_autonomous(params, h0, 5, 0.1, use_pallas=False)
    np.testing.assert_array_equal(traj[0], h0)


def test_rk4_rollout_solves_true_l96_when_field_is_exact():
    """Integrate the *true* normalized field with our scan-RK4 and compare
    against the numpy reference integrator: validates solver wiring
    independently of learning."""
    traj_ref = datasets.simulate_lorenz96_normalized(n_points=40)

    # Wrap the true normalized field as a "network": monkeypatch via a
    # custom param-free field using the ref path of step_autonomous is not
    # directly possible, so integrate manually with jax here.
    def step(h):
        dt = datasets.L96_DT
        f = lambda x: jnp.asarray(
            datasets.lorenz96_field_normalized(np.asarray(x)), jnp.float32
        )
        k1 = f(h)
        k2 = f(h + 0.5 * dt * k1)
        k3 = f(h + 0.5 * dt * k2)
        k4 = f(h + dt * k3)
        return h + dt / 6 * (k1 + 2 * k2 + 2 * k3 + k4)

    h = jnp.asarray(datasets.L96_Y0, jnp.float32)
    out = [np.asarray(h)]
    for _ in range(39):
        h = step(h)
        out.append(np.asarray(h))
    np.testing.assert_allclose(np.stack(out), traj_ref, atol=2e-3)


def test_field_driven_concat_order():
    # field_driven concatenates [x; h]: check against manual mlp_field.
    from compile.kernels import ref

    params = model.init_params((3, 6, 2), jax.random.PRNGKey(5))
    h = jnp.array([[0.1, 0.2]], jnp.float32)
    x = jnp.array([[0.9]], jnp.float32)
    got = model.field_driven(params, h, x)
    want = ref.mlp_field(params, jnp.array([[0.9, 0.1, 0.2]], jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-6)
