"""Training machinery: Adam correctness, smoke-scale fits, cell equations."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model, train


def test_adam_minimises_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = train.adam_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    grad = jax.grad(loss)
    for _ in range(800):
        params, state = train.adam_update(
            params, grad(params), state, lr=3e-2
        )
    assert float(loss(params)) < 1e-4


def test_adam_bias_correction_first_step():
    # After one step from zero moments, update ~= lr * sign(grad).
    params = {"x": jnp.array([1.0])}
    state = train.adam_init(params)
    grads = {"x": jnp.array([0.3])}
    new, _ = train.adam_update(params, grads, state, lr=0.1)
    assert abs(float(new["x"][0]) - 0.9) < 1e-3


def test_hp_collocation_smoke_converges():
    params, metrics = train.train_hp_node(
        seed=0, colloc_steps=300, rollout_steps=20
    )
    assert metrics["collocation_loss"] < 0.2
    assert len(params) == 3


def test_l96_node_smoke_shapes():
    params, metrics = train.train_l96_node(
        seed=0, colloc_steps=200, rollout_steps=10, hidden=16
    )
    assert params[0][0].shape == (6, 16)
    assert np.isfinite(metrics["collocation_l1"])


def test_rnn_cells_match_standard_equations():
    key = jax.random.PRNGKey(0)
    hidden, d = 4, 3
    for kind, gates in [("rnn", 1), ("gru", 3), ("lstm", 4)]:
        p = train.init_rnn(kind, d, hidden, key)
        assert p["wx"].shape == (d, gates * hidden)
        h = jnp.zeros((hidden,))
        c = jnp.zeros((hidden,))
        x = jnp.ones((d,))
        h2, c2 = train.rnn_cell(kind, p, h, c, x)
        assert h2.shape == (hidden,)
        assert np.all(np.isfinite(np.asarray(h2)))
        if kind == "lstm":
            assert not np.array_equal(np.asarray(c2), np.asarray(c))


def test_rnn_teacher_forcing_vs_autoregressive_first_step():
    # First prediction is identical under both modes (same inputs).
    key = jax.random.PRNGKey(1)
    p = train.init_rnn("gru", 6, 8, key)
    xs = jnp.asarray(
        datasets.simulate_lorenz96_normalized(n_points=10), jnp.float32
    )
    tf = train.rnn_rollout("gru", p, xs, teacher_forcing=True)
    ar = train.rnn_rollout("gru", p, xs, teacher_forcing=False)
    np.testing.assert_allclose(tf[0], ar[0], rtol=1e-6)


def test_json_roundtrip_params():
    params = model.init_params((2, 3, 1), jax.random.PRNGKey(2))
    obj = train.params_to_json(params, {"kind": "node"})
    back = train.json_to_params(obj)
    for (w1, b1), (w2, b2) in zip(params, back):
        np.testing.assert_allclose(w1, w2, rtol=1e-7)
        np.testing.assert_allclose(b1, b2, rtol=1e-7)
