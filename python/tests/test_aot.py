"""AOT lowering: HLO text validity and manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_produces_parseable_entry():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_pallas_kernel_lowers_into_hlo_text():
    from compile.kernels import crossbar

    def fn(v, gp, gn):
        return (crossbar.crossbar_vmm(v, gp, gn),)

    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(fn).lower(
        spec((8,), jnp.float32),
        spec((8, 4), jnp.float32),
        spec((8, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # interpret-mode pallas must lower to plain HLO (no mosaic custom-call).
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_rollout_lowering_contains_loop():
    params = model.init_params((6, 8, 8, 6), jax.random.PRNGKey(0))

    def fn(h0):
        return (model.rollout_autonomous(params, h0, 50, 0.02),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((6,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    # lax.scan lowers to a while loop — the artifact must contain one, not
    # a 50x unrolled body.
    assert "while" in text


def test_manifest_written_by_build(tmp_path=None):
    """If `make artifacts` has run, the manifest must be consistent."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {
        "hp_step",
        "hp_rollout",
        "l96_step_b1",
        "l96_step_b32",
        "l96_rollout",
        "crossbar_vmm",
    } <= names
    for a in manifest["artifacts"]:
        path = os.path.join(art, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            assert "ENTRY" in f.read()
    assert manifest["l96"]["scale"] == 8.0


def test_executed_artifact_matches_ref_rollout():
    """Execute the lowered rollout via jax and compare against the ref
    path — guards the exact function the Rust runtime loads."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    wpath = os.path.join(art, "weights", "l96_node.json")
    if not os.path.exists(wpath):
        pytest.skip("artifacts not built")
    from compile import train

    with open(wpath) as f:
        params = train.json_to_params(json.load(f))
    h0 = jnp.asarray(
        np.array([-1.2061, 0.0617, 1.1632, -1.5008, -1.5944, -0.0187]),
        jnp.float32,
    )
    a = model.rollout_autonomous(params, h0, 30, 0.02, use_pallas=True)
    b = model.rollout_autonomous(params, h0, 30, 0.02, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
