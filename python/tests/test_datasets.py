"""Ground-truth generators: physical invariants and paper constants."""

import numpy as np
import pytest

from compile import datasets


# ---------------------------------------------------------------------------
# HP memristor
# ---------------------------------------------------------------------------


def test_hp_resistance_endpoints():
    assert datasets.hp_resistance(np.array(0.0)) == datasets.HP_R_OFF
    assert datasets.hp_resistance(np.array(1.0)) == datasets.HP_R_ON


def test_hp_field_window_vanishes_at_boundaries():
    assert datasets.hp_field(np.array(0.0), np.array(1.0)) == 0.0
    assert datasets.hp_field(np.array(1.0), np.array(1.0)) == 0.0
    assert datasets.hp_field(np.array(0.5), np.array(1.0)) > 0.0


def test_hp_simulation_stays_physical():
    t, v, h, i = datasets.simulate_hp(datasets.STIMULI["sine"])
    assert len(t) == datasets.HP_NPOINTS
    assert np.all((h >= 0.0) & (h <= 1.0))
    assert np.all(np.isfinite(i))


def test_hp_sine_sweeps_wide_hysteresis():
    # With HP_K = 1e5 the sine stimulus must sweep a wide loop (this is the
    # Fig. 3i Lissajous requirement).
    _, _, h, _ = datasets.simulate_hp(datasets.STIMULI["sine"])
    assert h.max() - h.min() > 0.3, f"state swing {h.max() - h.min()}"


def test_hp_dc_zero_is_stationary():
    _, _, h, _ = datasets.simulate_hp(lambda t: np.zeros_like(np.asarray(t)),
                                      n_points=50)
    np.testing.assert_allclose(h, datasets.HP_H0)


@pytest.mark.parametrize("name", list(datasets.STIMULI))
def test_stimuli_bounded(name):
    t = np.linspace(0.0, 1.0, 2000)
    v = datasets.STIMULI[name](t)
    assert np.all(np.abs(v) <= 1.0 + 1e-12)


def test_rectangular_duty_cycle():
    v = datasets.rectangular_wave(freq=1.0, duty=0.25)(np.linspace(0, 0.99, 100))
    assert (v > 0).sum() == 25


# ---------------------------------------------------------------------------
# Lorenz96
# ---------------------------------------------------------------------------


def test_l96_field_equilibrium():
    x = np.full(6, datasets.L96_F)
    np.testing.assert_allclose(datasets.lorenz96_field(x), 0.0, atol=1e-12)


def test_l96_field_vectorised_over_batch():
    xs = np.random.default_rng(0).standard_normal((10, 6))
    batch = datasets.lorenz96_field(xs)
    rows = np.stack([datasets.lorenz96_field(r) for r in xs])
    np.testing.assert_allclose(batch, rows)


def test_l96_trajectory_shape_and_boundedness():
    traj = datasets.simulate_lorenz96(n_points=500)
    assert traj.shape == (500, 6)
    assert np.all(np.abs(traj) < 25.0)


def test_l96_normalized_convention():
    traj = datasets.simulate_lorenz96_normalized(n_points=100)
    np.testing.assert_allclose(traj[0], datasets.L96_Y0)
    assert np.all(np.abs(traj) < 3.0)
    # Normalized field consistency.
    xn = traj[50]
    fn = datasets.lorenz96_field_normalized(xn)
    fp = datasets.lorenz96_field(datasets.L96_SCALE * xn)
    np.testing.assert_allclose(fn * datasets.L96_SCALE, fp)


def test_l96_chaotic_mle_positive():
    mle = datasets.lorenz96_mle()
    assert 0.3 < mle < 2.0, mle


def test_l96_splits_match_figure_windows():
    assert datasets.L96_TRAIN_POINTS * datasets.L96_DT == pytest.approx(36.0)
    assert datasets.L96_NPOINTS * datasets.L96_DT == pytest.approx(48.0)
