"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and dtypes; every case asserts allclose between
the interpret-mode kernel and `kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import crossbar, odestep, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# crossbar_vmm
# ---------------------------------------------------------------------------


@given(
    b=st.integers(min_value=1, max_value=9),
    n=st.integers(min_value=1, max_value=40),
    m=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_crossbar_vmm_matches_ref(b, n, m, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    v = rand(k1, (b, n))
    gp = jax.random.uniform(k2, (n, m), jnp.float32, 0.0, 1e-4)
    gn = jax.random.uniform(k3, (n, m), jnp.float32, 0.0, 1e-4)
    got = crossbar.crossbar_vmm(v, gp, gn)
    want = ref.crossbar_vmm(v, gp, gn)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_crossbar_vmm_1d_input():
    key = jax.random.PRNGKey(0)
    v = rand(key, (32,))
    gp = jnp.full((32, 16), 5e-5, jnp.float32)
    gn = jnp.zeros((32, 16), jnp.float32)
    got = crossbar.crossbar_vmm(v, gp, gn)
    assert got.shape == (16,)
    np.testing.assert_allclose(got, ref.crossbar_vmm(v, gp, gn), rtol=1e-5)


def test_crossbar_vmm_batch_tiling_pads_correctly():
    # Batch not divisible by the tile: padding must not leak into results.
    key = jax.random.PRNGKey(1)
    v = rand(key, (5, 8))
    gp = jax.random.uniform(key, (8, 4), jnp.float32)
    gn = jnp.zeros((8, 4), jnp.float32)
    got = crossbar.crossbar_vmm(v, gp, gn, block_batch=2)
    np.testing.assert_allclose(
        got, ref.crossbar_vmm(v, gp, gn), rtol=1e-5, atol=1e-6
    )


def test_crossbar_vmm_differential_cancellation():
    # gp == gn -> exactly zero output.
    key = jax.random.PRNGKey(2)
    v = rand(key, (3, 10))
    g = jax.random.uniform(key, (10, 7), jnp.float32)
    out = crossbar.crossbar_vmm(v, g, g)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# fused RK4 step kernels
# ---------------------------------------------------------------------------


@given(
    b=st.integers(min_value=1, max_value=6),
    d=st.integers(min_value=1, max_value=8),
    h=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rk4_autonomous_matches_ref(b, d, h, seed):
    key = jax.random.PRNGKey(seed)
    params = model.init_params((d, h, h, d), key)
    hh = rand(jax.random.split(key)[0], (b, d))
    got = odestep.rk4_step_autonomous(params, hh, dt=0.02)
    want = ref.rk4_step_autonomous(params, hh, 0.02)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


@given(
    b=st.integers(min_value=1, max_value=5),
    di=st.integers(min_value=1, max_value=4),
    ds=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rk4_driven_matches_ref(b, di, ds, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = model.init_params((di + ds, 14, 14, ds), key)
    hh = rand(k1, (b, ds))
    x0, xh, x1 = rand(k2, (b, di)), rand(k3, (b, di)), rand(k4, (b, di))
    got = odestep.rk4_step_driven(params, hh, x0, xh, x1, dt=1e-3)
    want = ref.rk4_step_driven(params, hh, x0, xh, x1, 1e-3)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)


def test_rk4_autonomous_1d_squeeze():
    key = jax.random.PRNGKey(3)
    params = model.init_params((6, 16, 16, 6), key)
    h = rand(key, (6,))
    got = odestep.rk4_step_autonomous(params, h, dt=0.02)
    assert got.shape == (6,)
    want = ref.rk4_step_autonomous(params, h, 0.02)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)


def test_rk4_step_reduces_integration_error_vs_euler():
    # Sanity: the fused RK4 step integrates dh/dt = f(h) with 4th-order
    # accuracy. Use a linear field f(h) = -h via trained-free construction.
    d = 2
    w1 = jnp.array([[1.0, -1.0, 0, 0], [0, 0, 1.0, -1.0]], jnp.float32)
    b1 = jnp.zeros((4,), jnp.float32)
    w2 = jnp.array(
        [[-1.0, 0], [1.0, 0], [0, -1.0], [0, 1.0]], jnp.float32
    )
    b2 = jnp.zeros((2,), jnp.float32)
    params = [(w1, b1), (w2, b2)]
    h0 = jnp.array([1.0, -0.5], jnp.float32)
    dt = 0.1
    h = h0
    for _ in range(10):
        h = odestep.rk4_step_autonomous(params, h, dt=dt)
    want = np.asarray(h0) * np.exp(-1.0)
    np.testing.assert_allclose(np.asarray(h), want, atol=1e-5)


def test_dtype_bfloat16_runs_and_is_close():
    key = jax.random.PRNGKey(4)
    params = model.init_params((4, 8, 8, 4), key)
    h = rand(key, (3, 4)).astype(jnp.bfloat16)
    got = odestep.rk4_step_autonomous(params, h, dt=0.02)
    assert got.dtype == jnp.bfloat16
    want = ref.rk4_step_autonomous(params, h.astype(jnp.float32), 0.02)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), want, rtol=2e-2, atol=2e-2
    )


def test_block_batch_variants_agree():
    key = jax.random.PRNGKey(5)
    params = model.init_params((6, 32, 32, 6), key)
    h = rand(key, (13, 6))
    a = odestep.rk4_step_autonomous(params, h, dt=0.02, block_batch=4)
    b = odestep.rk4_step_autonomous(params, h, dt=0.02, block_batch=128)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
