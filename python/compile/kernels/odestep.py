"""Pallas kernel: fused RK4 neural-ODE step.

The memristive solver's defining property is that the *entire* ODE step —
three crossbar layers, analogue ReLU between them, and the integrator — runs
without leaving the analogue domain. The TPU counterpart is a single fused
kernel: all layer weights pinned in VMEM (constant BlockSpec index_map), all
four RK4 stages and the state update computed in-register per batch tile, so
one kernel invocation advances the twin one time step with zero HBM round
trips for intermediates.

Two variants mirror the paper's two twins:

* ``autonomous`` — dh/dt = f(h)            (Lorenz96, Fig. 4b)
* ``driven``     — dh/dt = f([x(t); h])    (HP memristor, Fig. 3b)

VMEM budget (f32): the Fig. 3 net (2x14, 14x14, 14x1) is < 2 KB; the largest
Fig. 4h sweep point (hidden 512: 6x512, 512x512, 512x6) is ~1.05 MB — far
below the ~16 MB/core VMEM, so "weights resident for the whole rollout" holds
at every size the paper evaluates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp(u, ws, bs):
    """ReLU MLP with linear head; accumulation forced to f32 (MXU-style)."""
    h = u
    for k, (w, b) in enumerate(zip(ws, bs)):
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if k + 1 < len(ws):
            h = jnp.maximum(h, 0.0)
    return h


def _autonomous_kernel(dt, n_layers, h_ref, *refs):
    w_refs, b_refs, o_ref = refs[:n_layers], refs[n_layers:-1], refs[-1]
    ws = [r[...].astype(jnp.float32) for r in w_refs]
    bs = [r[...].astype(jnp.float32) for r in b_refs]
    h = h_ref[...].astype(jnp.float32)
    k1 = _mlp(h, ws, bs)
    k2 = _mlp(h + 0.5 * dt * k1, ws, bs)
    k3 = _mlp(h + 0.5 * dt * k2, ws, bs)
    k4 = _mlp(h + dt * k3, ws, bs)
    o_ref[...] = (h + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)).astype(
        o_ref.dtype
    )


def _driven_kernel(dt, n_layers, h_ref, x0_ref, xh_ref, x1_ref, *refs):
    w_refs, b_refs, o_ref = refs[:n_layers], refs[n_layers:-1], refs[-1]
    ws = [r[...].astype(jnp.float32) for r in w_refs]
    bs = [r[...].astype(jnp.float32) for r in b_refs]
    h = h_ref[...].astype(jnp.float32)
    x0 = x0_ref[...].astype(jnp.float32)
    xh = xh_ref[...].astype(jnp.float32)
    x1 = x1_ref[...].astype(jnp.float32)

    def f(hh, xx):
        return _mlp(jnp.concatenate([xx, hh], axis=-1), ws, bs)

    k1 = f(h, x0)
    k2 = f(h + 0.5 * dt * k1, xh)
    k3 = f(h + 0.5 * dt * k2, xh)
    k4 = f(h + dt * k3, x1)
    o_ref[...] = (h + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)).astype(
        o_ref.dtype
    )


def _weight_specs(params):
    """Whole-array, grid-invariant BlockSpecs: weights stay VMEM-resident."""
    specs = []
    for w, _ in params:
        # n=w.ndim binds per-iteration (late-binding closure pitfall).
        specs.append(pl.BlockSpec(w.shape, lambda i, n=w.ndim: (0,) * n))
    for _, b in params:
        specs.append(pl.BlockSpec(b.shape, lambda i, n=b.ndim: (0,) * n))
    return specs


def _flatten(params):
    return [w for w, _ in params] + [b for _, b in params]


@functools.partial(jax.jit, static_argnames=("dt", "block_batch"))
def rk4_step_autonomous(params, h, *, dt: float, block_batch: int = 128):
    """Fused RK4 step for an autonomous neural ODE. h: [b, d] or [d]."""
    squeeze = h.ndim == 1
    if squeeze:
        h = h[None, :]
    b, d = h.shape
    tile = min(block_batch, b)
    pad = (-b) % tile
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
    kernel = functools.partial(_autonomous_kernel, dt, len(params))
    out = pl.pallas_call(
        kernel,
        grid=(h.shape[0] // tile,),
        in_specs=[pl.BlockSpec((tile, d), lambda i: (i, 0))]
        + _weight_specs(params),
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h.shape[0], d), h.dtype),
        interpret=True,
    )(h, *_flatten(params))
    out = out[:b]
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("dt", "block_batch"))
def rk4_step_driven(params, h, x0, xh, x1, *, dt: float, block_batch: int = 128):
    """Fused RK4 step for a driven neural ODE.

    h: [b, d_state]; x0/xh/x1: [b, d_in] stimulus at t, t+dt/2, t+dt.
    1-D inputs are treated as a single-element batch.
    """
    squeeze = h.ndim == 1
    if squeeze:
        h, x0, xh, x1 = h[None], x0[None], xh[None], x1[None]
    b, d = h.shape
    di = x0.shape[-1]
    tile = min(block_batch, b)
    pad = (-b) % tile
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        x0 = jnp.pad(x0, ((0, pad), (0, 0)))
        xh = jnp.pad(xh, ((0, pad), (0, 0)))
        x1 = jnp.pad(x1, ((0, pad), (0, 0)))
    kernel = functools.partial(_driven_kernel, dt, len(params))
    tile_spec = lambda cols: pl.BlockSpec((tile, cols), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(h.shape[0] // tile,),
        in_specs=[tile_spec(d), tile_spec(di), tile_spec(di), tile_spec(di)]
        + _weight_specs(params),
        out_specs=tile_spec(d),
        out_shape=jax.ShapeDtypeStruct((h.shape[0], d), h.dtype),
        interpret=True,
    )(h, x0, xh, x1, *_flatten(params))
    out = out[:b]
    return out[0] if squeeze else out
