"""Pallas kernel: differential-pair crossbar vector-matrix multiply.

This is the paper's compute hot-spot expressed for a TPU-style memory
hierarchy. The analogue array computes I = V.G in-place in the crossbar; the
TPU analogue is to keep the conductance matrices resident in VMEM for the
whole invocation and stream only the (batched) voltage vectors, tiling the
batch dimension with a BlockSpec grid so each grid step works on one
VMEM-sized tile of inputs while the weights are pinned (index_map constant in
the grid index).

Lowered with ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU numbers are projected from the VMEM footprint
and MXU shapes in DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmm_kernel(v_ref, gp_ref, gn_ref, o_ref):
    """One batch tile: o = v @ (gp - gn).

    ``gp/gn`` arrive as whole-array blocks (weights stay resident across the
    grid); ``v``/``o`` are [tile, n] / [tile, m] batch tiles. The subtraction
    and the matmul both map onto the VPU/MXU; accumulation is in f32
    regardless of input dtype, mirroring how column currents sum linearly in
    the analogue array.
    """
    v = v_ref[...].astype(jnp.float32)
    g = gp_ref[...].astype(jnp.float32) - gn_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(v, g, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_batch",))
def crossbar_vmm(v, gp, gn, *, block_batch: int = 128):
    """Batched differential crossbar VMM via pallas_call.

    v:  [b, n] or [n]   input voltages
    gp: [n, m]          positive-pair conductances
    gn: [n, m]          negative-pair conductances
    returns [b, m] (or [m]) column currents, same dtype as ``v``.
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[None, :]
    b, n = v.shape
    m = gp.shape[1]
    tile = min(block_batch, b)
    # Pad the batch to a whole number of tiles; pallas grids are static.
    pad = (-b) % tile
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    grid = (v.shape[0] // tile,)
    out = pl.pallas_call(
        _vmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n, m), lambda i: (0, 0)),  # weights pinned
            pl.BlockSpec((n, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v.shape[0], m), v.dtype),
        interpret=True,
    )(v, gp, gn)
    out = out[:b]
    return out[0] if squeeze else out
