"""L1: Pallas kernels for the paper's compute hot-spot.

``crossbar``  — differential-pair crossbar VMM (Fig. 2f);
``odestep``   — fused RK4 neural-ODE step (the whole solver in one kernel);
``ref``       — pure-jnp oracles used by pytest and the training path.
"""
