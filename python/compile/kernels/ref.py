"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact reference here; pytest
(``python/tests/``) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes and dtypes. The oracles are also the L2
fallback path (``use_pallas=False`` in ``model.py``).
"""

from __future__ import annotations

import jax.numpy as jnp


def crossbar_vmm(v, gp, gn):
    """Differential-pair crossbar vector-matrix multiply.

    The analogue array realises ``i_j = sum_i v_i (Gp_ij - Gn_ij)`` via Ohm's
    law (per-cell multiplication) and Kirchhoff's current law (per-column
    summation); adjacent columns carry +v and -v so a conductance *pair*
    encodes a signed weight (paper Fig. 2f).

    v:  [..., n]  input voltages (rows / bit lines)
    gp: [n, m]    positive-column conductances
    gn: [n, m]    negative-column conductances
    returns [..., m] column currents.
    """
    return jnp.matmul(v, gp - gn)


def mlp_field(params, u):
    """Three-layer MLP vector field f(u) with ReLU hidden activations.

    ``params`` is a list of (w, b) with w: [fan_in, fan_out]. The final layer
    is linear (the paper uses ReLU everywhere except the output layer).
    """
    h = u
    for w, b in params[:-1]:
        h = jnp.maximum(jnp.matmul(h, w) + b, 0.0)
    w, b = params[-1]
    return jnp.matmul(h, w) + b


def rk4_step_autonomous(params, h, dt):
    """One classic RK4 step of dh/dt = f(h) (Lorenz96 twin: no stimulus)."""
    k1 = mlp_field(params, h)
    k2 = mlp_field(params, h + 0.5 * dt * k1)
    k3 = mlp_field(params, h + 0.5 * dt * k2)
    k4 = mlp_field(params, h + dt * k3)
    return h + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)


def rk4_step_driven(params, h, x0, xh, x1, dt):
    """One RK4 step of dh/dt = f([x(t); h]) with external stimulus x.

    x0 / xh / x1 are the stimulus samples at t, t + dt/2 and t + dt
    (the half-step sample is what distinguishes a genuinely continuous-time
    solver from the recurrent-ResNet Euler baseline).
    Shapes: h [..., d_state], x* [..., d_in].
    """

    def f(hh, xx):
        return mlp_field(params, jnp.concatenate([xx, hh], axis=-1))

    k1 = f(h, x0)
    k2 = f(h + 0.5 * dt * k1, xh)
    k3 = f(h + 0.5 * dt * k2, xh)
    k4 = f(h + dt * k3, x1)
    return h + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
