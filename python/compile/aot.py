"""AOT entry point: train (cached), export weights, lower HLO artifacts.

Run as ``python -m compile.aot --outdir ../artifacts`` (the Makefile's
``artifacts`` target). Produces:

* ``weights/*.json``      — trained parameters for the Rust analogue backend
                            and the Rust-native baseline models;
* ``*.hlo.txt``           — HLO **text** modules (the interchange format the
                            ``xla`` crate's 0.5.1 extension can parse; jax's
                            serialized protos use 64-bit ids it rejects);
* ``manifest.json``       — artifact index (entry names, shapes, dtypes)
                            consumed by ``rust/src/runtime/artifacts.rs``.

Python runs ONCE at build time; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import datasets, model, train

# Trajectory lengths baked into the rollout executables.
HP_STEPS = datasets.HP_NPOINTS - 1  # 499 RK4 steps -> 500-sample trajectory
L96_STEPS = datasets.L96_NPOINTS - 1  # 2399 steps -> 2400-sample trajectory


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big literals as `constant({...})`, which the xla_extension 0.5.1 text
    # parser silently reads back as zeros — the baked weights vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Weight training / caching
# ---------------------------------------------------------------------------


def ensure_weights(outdir: str, retrain: bool) -> dict:
    wdir = os.path.join(outdir, "weights")
    os.makedirs(wdir, exist_ok=True)
    report_path = os.path.join(wdir, "training_report.json")
    report = {}
    if os.path.exists(report_path) and not retrain:
        with open(report_path) as f:
            report = json.load(f)

    def cached(name, trainer, to_json):
        path = os.path.join(wdir, f"{name}.json")
        if os.path.exists(path) and not retrain:
            with open(path) as f:
                return json.load(f)
        print(f"[aot] training {name} ...")
        params, metrics = trainer()
        obj = to_json(params, metrics)
        train.save_json(obj, path)
        report[name] = metrics
        return obj

    hp_node = cached(
        "hp_node",
        train.train_hp_node,
        lambda p, m: train.params_to_json(
            p,
            {
                "kind": "node",
                "task": "hp",
                "layers": list(model.HP_LAYERS),
                "dt": datasets.HP_DT,
                "metrics": m,
            },
        ),
    )
    hp_resnet = cached(
        "hp_resnet",
        train.train_hp_resnet,
        lambda p, m: train.params_to_json(
            p,
            {
                "kind": "resnet",
                "task": "hp",
                "layers": list(model.HP_LAYERS),
                "dt": datasets.HP_DT,
                "metrics": m,
            },
        ),
    )
    l96_node = cached(
        "l96_node",
        train.train_l96_node,
        lambda p, m: train.params_to_json(
            p,
            {
                "kind": "node",
                "task": "l96",
                "layers": [datasets.L96_DIM, 64, 64, datasets.L96_DIM],
                "dt": datasets.L96_DT,
                "metrics": m,
            },
        ),
    )
    baselines = {}
    for kind in ("rnn", "gru", "lstm"):
        baselines[kind] = cached(
            f"l96_{kind}",
            lambda kind=kind: train.train_l96_rnn(kind),
            lambda p, m, kind=kind: train.rnn_to_json(
                p,
                {
                    "kind": kind,
                    "task": "l96",
                    "hidden": 64,
                    "d_in": datasets.L96_DIM,
                    "dt": datasets.L96_DT,
                    "metrics": m,
                },
            ),
        )
    with open(report_path, "w") as f:
        json.dump(report, f, indent=2)
    return {
        "hp_node": hp_node,
        "hp_resnet": hp_resnet,
        "l96_node": l96_node,
        **{f"l96_{k}": v for k, v in baselines.items()},
    }


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------


def build_entries(weights: dict):
    """Each entry: (name, jitted fn with weights baked, example arg specs).

    All step/rollout entries lower through the Pallas kernels (L1 fuses
    into the exported HLO). Historical note: these artifacts once executed
    wrongly in Rust because `as_hlo_text()` elides large constants by
    default (`constant({...})`) and the 0.5.1 text parser zero-fills them
    — fixed by `print_large_constants=True` in `to_hlo_text`.
    """
    hp_params = train.json_to_params(weights["hp_node"])
    l96_params = train.json_to_params(weights["l96_node"])
    hp_dt = float(weights["hp_node"]["meta"]["dt"])
    l96_dt = float(weights["l96_node"]["meta"]["dt"])
    d = datasets.L96_DIM

    def hp_step(h, x0, xh, x1):
        return (model.step_driven(hp_params, h, x0, xh, x1, hp_dt),)

    def hp_rollout(h0, xs_half):
        return (model.rollout_driven(hp_params, h0, xs_half, hp_dt),)

    def l96_step_b1(h):
        return (model.step_autonomous(l96_params, h, l96_dt),)

    def l96_step_b32(h):
        return (model.step_autonomous(l96_params, h, l96_dt),)

    def l96_rollout(h0):
        return (model.rollout_autonomous(l96_params, h0, L96_STEPS, l96_dt),)

    def crossbar_vmm(v, gp, gn):
        from compile.kernels import crossbar

        return (crossbar.crossbar_vmm(v, gp, gn),)

    return [
        ("hp_step", hp_step, [_spec((1,)), _spec((1,)), _spec((1,)), _spec((1,))]),
        (
            "hp_rollout",
            hp_rollout,
            [_spec((1,)), _spec((2 * HP_STEPS + 1, 1))],
        ),
        ("l96_step_b1", l96_step_b1, [_spec((d,))]),
        ("l96_step_b32", l96_step_b32, [_spec((32, d))]),
        ("l96_rollout", l96_rollout, [_spec((d,))]),
        (
            "crossbar_vmm",
            crossbar_vmm,
            [_spec((32,)), _spec((32, 32)), _spec((32, 32))],
        ),
    ]


def lower_all(outdir: str, weights: dict) -> dict:
    manifest = {"artifacts": []}
    for name, fn, specs in build_entries(weights):
        print(f"[aot] lowering {name} ...")
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        out_shapes = [
            list(o.shape) for o in jax.eval_shape(fn, *specs)
        ]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in specs],
                "outputs": out_shapes,
                "dtype": "f32",
                "return_tuple": True,
            }
        )
    manifest["hp"] = {
        "dt": datasets.HP_DT,
        "n_points": datasets.HP_NPOINTS,
        "layers": list(model.HP_LAYERS),
    }
    manifest["l96"] = {
        "dt": datasets.L96_DT,
        "n_points": datasets.L96_NPOINTS,
        "train_points": datasets.L96_TRAIN_POINTS,
        "dim": datasets.L96_DIM,
        # Normalized-space initial condition (the paper's convention: state
        # = physical / scale; see datasets.py).
        "y0": datasets.L96_Y0.tolist(),
        "scale": datasets.L96_SCALE,
        "forcing": datasets.L96_F,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--retrain", action="store_true", help="ignore cached weights"
    )
    ap.add_argument(
        "--skip-hlo",
        action="store_true",
        help="only train/export weights (used by fast CI loops)",
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    weights = ensure_weights(args.outdir, args.retrain)
    if not args.skip_hlo:
        manifest = lower_all(args.outdir, weights)
        n = len(manifest["artifacts"])
        print(f"[aot] wrote {n} HLO artifacts + manifest to {args.outdir}")


if __name__ == "__main__":
    main()
