"""L2: the neural-ODE compute graphs for both digital twins.

Defines the MLP vector field, the fused-kernel RK4 steps (delegating the
hot-spot to the Pallas kernels in ``kernels/``) and full trajectory rollouts
as ``lax.scan`` loops so the AOT-lowered HLO contains a single compiled loop
body instead of an unrolled graph.

Everything here is build-time Python: ``aot.py`` lowers these functions once
to HLO text, and the Rust runtime executes the artifacts on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import odestep, ref

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

# The paper's two architectures (Fig. 3b and Fig. 4b / Methods).
HP_LAYERS = (2, 14, 14, 1)  # [v; h] -> dh/dt
L96_LAYERS = (6, 64, 64, 6)  # h -> dh/dt (autonomous)


def init_params(layers, key, scale: float | None = None):
    """He-uniform init for a ReLU MLP; params as [(w, b), ...] f32."""
    params = []
    for fan_in, fan_out in zip(layers[:-1], layers[1:]):
        key, sub = jax.random.split(key)
        bound = scale if scale is not None else float(np.sqrt(2.0 / fan_in))
        w = jax.random.uniform(
            sub, (fan_in, fan_out), jnp.float32, -bound, bound
        )
        params.append((w, jnp.zeros((fan_out,), jnp.float32)))
    return params


def params_to_pytree(params):
    return {f"w{i}": w for i, (w, _) in enumerate(params)} | {
        f"b{i}": b for i, (_, b) in enumerate(params)
    }


def pytree_to_params(tree):
    n = len(tree) // 2
    return [(tree[f"w{i}"], tree[f"b{i}"]) for i in range(n)]


# ---------------------------------------------------------------------------
# Vector fields and single steps
# ---------------------------------------------------------------------------


def field_autonomous(params, h):
    """dh/dt = f(h), pure-jnp (training path: differentiable, no pallas)."""
    return ref.mlp_field(params, h)


def field_driven(params, h, x):
    """dh/dt = f([x; h]), pure-jnp."""
    return ref.mlp_field(params, jnp.concatenate([x, h], axis=-1))


def step_autonomous(params, h, dt: float, use_pallas: bool = True):
    """One RK4 step of the autonomous twin (Lorenz96)."""
    if use_pallas:
        return odestep.rk4_step_autonomous(params, h, dt=dt)
    return ref.rk4_step_autonomous(params, h, dt)


def step_driven(params, h, x0, xh, x1, dt: float, use_pallas: bool = True):
    """One RK4 step of the driven twin (HP memristor)."""
    if use_pallas:
        return odestep.rk4_step_driven(params, h, x0, xh, x1, dt=dt)
    return ref.rk4_step_driven(params, h, x0, xh, x1, dt)


# ---------------------------------------------------------------------------
# Rollouts (lax.scan — one fused loop in the lowered HLO)
# ---------------------------------------------------------------------------


def rollout_autonomous(params, h0, n_steps: int, dt: float, use_pallas=True):
    """Integrate the autonomous twin for ``n_steps``; returns [n_steps+1, d].

    The scan carries only the state vector; weights are loop-invariant and
    XLA hoists them out of the while-loop body, matching the "weights stay in
    the array" analogue execution model.
    """

    def body(h, _):
        h2 = step_autonomous(params, h, dt, use_pallas)
        return h2, h2

    _, hs = jax.lax.scan(body, h0, None, length=n_steps)
    return jnp.concatenate([h0[None], hs], axis=0)


def rollout_driven(params, h0, xs_half, dt: float, use_pallas=True):
    """Integrate the driven twin against a stimulus sampled at dt/2.

    xs_half: [2*n_steps + 1, d_in] stimulus at t = 0, dt/2, dt, ... so each
    RK4 step sees x(t), x(t+dt/2), x(t+dt) without interpolation error.
    Returns [n_steps+1, d_state].
    """
    n_steps = (xs_half.shape[0] - 1) // 2
    x0s = xs_half[0 : 2 * n_steps : 2]
    xhs = xs_half[1 : 2 * n_steps : 2]
    x1s = xs_half[2 : 2 * n_steps + 1 : 2]

    def body(h, xs):
        x0, xh, x1 = xs
        h2 = step_driven(params, h, x0, xh, x1, dt, use_pallas)
        return h2, h2

    _, hs = jax.lax.scan(body, h0, (x0s, xhs, x1s))
    return jnp.concatenate([h0[None], hs], axis=0)


# ---------------------------------------------------------------------------
# Differentiable (training) variants — pure jnp, used by train.py.
# ---------------------------------------------------------------------------


def rollout_autonomous_ref(params, h0, n_steps: int, dt: float):
    return rollout_autonomous(params, h0, n_steps, dt, use_pallas=False)


def rollout_driven_ref(params, h0, xs_half, dt: float):
    return rollout_driven(params, h0, xs_half, dt, use_pallas=False)
