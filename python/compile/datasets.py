"""Ground-truth dynamical systems and stimulation waveforms.

Implements the two physical assets the paper builds digital twins of:

* the HP (Hewlett-Packard) current-controlled memristor, Eqs. (2)-(3) of the
  paper (Strukov et al. 2008; Radwan et al. 2010 model for periodic signals),
  with a Joglekar window to keep the state bounded, and
* the Lorenz96 atmospheric dynamics, Eq. (4), with periodic boundary
  conditions.

Both are integrated with a classic RK4 scheme at fine resolution; these
trajectories are the *ground truth* for training and for every accuracy
figure (Fig. 3f-j, Fig. 4d-g).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# HP memristor ground truth (the twinned asset of Fig. 3)
# ---------------------------------------------------------------------------

# Canonical HP-memristor constants (Strukov 2008). The state is normalised to
# h = w/D in [0, 1]; the drift rate constant follows from
# dh/dt = mu_v * R_ON / D^2 * i  with  mu_v = 1e-14 m^2 s^-1 V^-1,
# R_ON = 100 Ohm, D ~ 3.2 nm  ->  mu_v * R_ON / D^2 ~ 1e5 (1/(Ohm s)).
# (D = 3.2 nm rather than Strukov's 10 nm so the Fig. 3 stimuli sweep a wide
# hysteresis loop within the paper's 0.5 s observation window.)
HP_R_ON = 100.0  # Ohm, fully-doped resistance
HP_R_OFF = 16_000.0  # Ohm, undoped resistance
HP_K = 1.0e5  # mu_v * R_ON / D^2  [1/(Ohm s)] drift prefactor
HP_DT = 1.0e-3  # s, paper samples 500 points at dt = 1e-3 s
HP_NPOINTS = 500  # paper: 500-point training trajectories
HP_H0 = 0.1  # initial boundary position w/D


def hp_resistance(h: np.ndarray) -> np.ndarray:
    """Eq. (2): two-resistor series model, R(h) = R_ON h + R_OFF (1 - h)."""
    return HP_R_ON * h + HP_R_OFF * (1.0 - h)


def hp_field(h: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Eq. (3) with a Joglekar p=1 window 4h(1-h).

    The window keeps the doped-region boundary inside the device (h in
    [0, 1]) exactly as physical HP memristors saturate at their terminals;
    the factor 4 normalises the window peak to 1 at h = 1/2.
    """
    window = 4.0 * h * (1.0 - h)
    return HP_K * v / hp_resistance(h) * window


def hp_current(h: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Ohmic conduction: i = v / R(h)."""
    return v / hp_resistance(h)


def simulate_hp(
    v_fn,
    n_points: int = HP_NPOINTS,
    dt: float = HP_DT,
    h0: float = HP_H0,
    substeps: int = 8,
):
    """Integrate the HP memristor under a voltage stimulus.

    Returns (t, v, h, i): time stamps, applied voltage, state trajectory and
    device current, each of length ``n_points``. RK4 with ``substeps``
    sub-intervals per sample keeps the ground truth far below the twin's own
    truncation error.
    """
    t = np.arange(n_points) * dt
    h = np.empty(n_points)
    h[0] = h0
    hd = dt / substeps
    for k in range(n_points - 1):
        x = h[k]
        tk = t[k]
        for s in range(substeps):
            ts = tk + s * hd
            k1 = hp_field(x, v_fn(ts))
            k2 = hp_field(x + 0.5 * hd * k1, v_fn(ts + 0.5 * hd))
            k3 = hp_field(x + 0.5 * hd * k2, v_fn(ts + 0.5 * hd))
            k4 = hp_field(x + hd * k3, v_fn(ts + hd))
            x = x + hd / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
            x = min(max(x, 0.0), 1.0)
        h[k + 1] = x
    v = v_fn(t)
    return t, v, h, v / hp_resistance(h)


# ---------------------------------------------------------------------------
# Stimulation waveforms (Fig. 3f/j: sine, triangular, rectangular, mod-sine)
# ---------------------------------------------------------------------------


def sine_wave(amp: float = 1.0, freq: float = 4.0, phase: float = 0.0):
    def v(t):
        return amp * np.sin(2.0 * np.pi * freq * np.asarray(t) + phase)

    return v


def triangular_wave(amp: float = 1.0, freq: float = 4.0):
    def v(t):
        ph = (np.asarray(t) * freq) % 1.0
        return amp * (4.0 * np.abs(ph - 0.5) - 1.0)

    return v


def rectangular_wave(amp: float = 1.0, freq: float = 4.0, duty: float = 0.5):
    def v(t):
        ph = (np.asarray(t) * freq) % 1.0
        return np.where(ph < duty, amp, -amp)

    return v


def modulated_sine_wave(amp: float = 1.0, freq: float = 4.0, mod_freq: float = 1.0):
    """Amplitude-modulated sine, the paper's fourth stimulus."""

    def v(t):
        t = np.asarray(t)
        envelope = 0.5 * (1.0 + np.sin(2.0 * np.pi * mod_freq * t))
        return amp * envelope * np.sin(2.0 * np.pi * freq * t)

    return v


STIMULI = {
    "sine": sine_wave(),
    "triangular": triangular_wave(),
    "rectangular": rectangular_wave(),
    "modulated": modulated_sine_wave(),
}


# ---------------------------------------------------------------------------
# Lorenz96 dynamics (the twinned asset of Fig. 4)
# ---------------------------------------------------------------------------

L96_DIM = 6  # paper trains a d = 6 twin
L96_F = 8.0  # canonical forcing; chaotic regime for n >= 5
L96_DT = 0.02  # s; 2400 samples span the paper's 48 s window
L96_NPOINTS = 2400  # sequence length (1800 interpolation + 600 extrapolation)
L96_TRAIN_POINTS = 1800
# Initial condition quoted verbatim in the paper's Methods. Its ~[-1.6, 1.2]
# range reveals the paper works in *normalized* units: the F = 8 attractor
# spans ~[-8, 13], so states are scaled by 1/F. The twin (and all error
# metrics: L1 0.512 interp / 0.321 extrap) live in normalized space; the
# physical trajectory is SCALE * normalized.
L96_SCALE = 8.0
L96_Y0 = np.array([-1.2061, 0.0617, 1.1632, -1.5008, -1.5944, -0.0187])


def simulate_lorenz96_normalized(
    n_points: int = L96_NPOINTS,
    dt: float = L96_DT,
    forcing: float = L96_F,
    substeps: int = 4,
) -> np.ndarray:
    """Paper-convention trajectory: integrate the physical dynamics from
    SCALE * Y0 and return states divided by SCALE (shape [n_points, d])."""
    phys = simulate_lorenz96(
        L96_SCALE * L96_Y0, n_points, dt, forcing, substeps
    )
    return phys / L96_SCALE


def lorenz96_field_normalized(
    xn: np.ndarray, forcing: float = L96_F
) -> np.ndarray:
    """Vector field in normalized coordinates: d(x/S)/dt = f(S x_n)/S."""
    return lorenz96_field(L96_SCALE * xn, forcing) / L96_SCALE


def lorenz96_field(x: np.ndarray, forcing: float = L96_F) -> np.ndarray:
    """Eq. (4): dx_i/dt = (x_{i+1} - x_{i-2}) x_{i-1} - x_i + F, periodic.

    Vectorised over leading axes (the state index is the last axis).
    """
    return (
        (np.roll(x, -1, axis=-1) - np.roll(x, 2, axis=-1))
        * np.roll(x, 1, axis=-1)
        - x
        + forcing
    )


def simulate_lorenz96(
    x0: np.ndarray = L96_Y0,
    n_points: int = L96_NPOINTS,
    dt: float = L96_DT,
    forcing: float = L96_F,
    substeps: int = 4,
) -> np.ndarray:
    """RK4-integrate Lorenz96; returns trajectory of shape (n_points, d)."""
    x = np.array(x0, dtype=np.float64)
    out = np.empty((n_points, x.size))
    out[0] = x
    hd = dt / substeps
    for k in range(1, n_points):
        for _ in range(substeps):
            k1 = lorenz96_field(x, forcing)
            k2 = lorenz96_field(x + 0.5 * hd * k1, forcing)
            k3 = lorenz96_field(x + 0.5 * hd * k2, forcing)
            k4 = lorenz96_field(x + hd * k3, forcing)
            x = x + hd / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
        out[k] = x
    return out


def lorenz96_mle(forcing: float = L96_F, dim: int = L96_DIM) -> float:
    """Benettin estimate of the maximal Lyapunov exponent (Methods, Eq. 10).

    Used to express extrapolation horizons in Lyapunov times.
    """
    rng = np.random.default_rng(0)
    x = L96_Y0[:dim].copy()
    d0 = 1e-8
    y = x + d0 * rng.standard_normal(dim) / np.sqrt(dim)
    dt, n_steps, warmup = 0.01, 20_000, 2_000
    acc = 0.0

    def step(z):
        k1 = lorenz96_field(z, forcing)
        k2 = lorenz96_field(z + 0.5 * dt * k1, forcing)
        k3 = lorenz96_field(z + 0.5 * dt * k2, forcing)
        k4 = lorenz96_field(z + dt * k3, forcing)
        return z + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)

    for k in range(n_steps):
        x, y = step(x), step(y)
        d = np.linalg.norm(y - x)
        if k >= warmup:
            acc += np.log(d / d0)
        y = x + (y - x) * (d0 / d)
    return acc / ((n_steps - warmup) * dt)
