"""Build-time training of the digital twins and their digital baselines.

Mirrors the paper's Methods section:

* the neural-ODE twins are trained by backpropagating through the RK4 solver
  ("discretize-then-optimize", gradient-equivalent to the adjoint method for
  this solver/step size) with Adam, after a collocation warm-start on the
  vector field;
* training data are the ground-truth trajectories of ``datasets.py`` —
  500 points at dt = 1e-3 s for the HP memristor, 1800/2400 points at
  dt = 0.02 s for Lorenz96 (interpolation split per the paper);
* Gaussian state noise is injected during Lorenz96 training as a regulariser
  (the paper's neural-SDE-style stabilisation, ref. 46);
* the comparison baselines (recurrent ResNet for Fig. 3j; RNN/GRU/LSTM for
  Fig. 4g-i) are trained on the same data with the same budget.

Everything runs in well under two minutes on CPU; ``aot.py`` caches results
under ``artifacts/weights/`` and only retrains when inputs change.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model

# ---------------------------------------------------------------------------
# A tiny Adam (optax is not available in the offline image)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + eps),
        params,
        m,
        v,
    )
    return new, {"m": m, "v": v, "t": t}


def _fit(loss_fn, params, steps, lr, log_every=0, tag=""):
    """Generic full-batch Adam loop over a jitted scalar loss."""
    state = adam_init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    for k in range(steps):
        loss, grads = grad_fn(params)
        params, state = adam_update(params, grads, state, lr=lr)
        if log_every and (k % log_every == 0 or k == steps - 1):
            print(f"  [{tag}] step {k:5d} loss {float(loss):.6f}")
    return params, float(loss)


# ---------------------------------------------------------------------------
# HP-memristor neural ODE (Fig. 3)
# ---------------------------------------------------------------------------


def train_hp_node(seed: int = 0, colloc_steps=3000, rollout_steps=400):
    """Train f([v; h]) ~ dh/dt for the HP memristor twin.

    Phase 1 (collocation): regress the analytic field on a (h, v) grid —
    cheap and conditions the network. Phase 2: backprop through RK4 rollouts
    of the sine + triangular stimuli (the paper's training stimuli; square and
    modulated-sine test extrapolation), minimising the L1 trajectory error as
    in the Methods.
    """
    key = jax.random.PRNGKey(seed)
    params = model.init_params(model.HP_LAYERS, key)

    # --- collocation grid over the state/input box
    hs = np.linspace(0.02, 0.98, 49)
    vs = np.linspace(-1.0, 1.0, 41)
    hh, vv = np.meshgrid(hs, vs, indexing="ij")
    u = jnp.asarray(
        np.stack([vv.ravel(), hh.ravel()], axis=-1), jnp.float32
    )  # [N, 2] = [v, h]
    target = jnp.asarray(
        datasets.hp_field(hh.ravel(), vv.ravel()), jnp.float32
    )[:, None]
    # Scale compresses the field's dynamic range (|f| up to ~40 s^-1).
    fscale = float(np.abs(target).max())

    def colloc_loss(p):
        pred = model.field_driven(p, u[:, 1:2], u[:, 0:1])
        return jnp.mean(jnp.abs(pred - target)) / fscale

    params, closs = _fit(
        colloc_loss, params, colloc_steps, 3e-3, 500, "hp-colloc"
    )

    # --- trajectory fine-tune through the RK4 solver
    dt = datasets.HP_DT
    n = datasets.HP_NPOINTS
    t_half = np.arange(2 * (n - 1) + 1) * (dt / 2.0)
    trajs = []
    for name in ("sine", "triangular"):
        v_fn = datasets.STIMULI[name]
        _, _, h, _ = datasets.simulate_hp(v_fn, n_points=n, dt=dt)
        xs_half = jnp.asarray(v_fn(t_half), jnp.float32)[:, None]
        trajs.append((xs_half, jnp.asarray(h, jnp.float32)[:, None]))

    def rollout_loss(p):
        loss = 0.0
        for xs_half, h_true in trajs:
            pred = model.rollout_driven_ref(p, h_true[0], xs_half, dt)
            loss = loss + jnp.mean(jnp.abs(pred - h_true))
        return loss / len(trajs)

    params, rloss = _fit(
        rollout_loss, params, rollout_steps, 1e-3, 100, "hp-rollout"
    )
    return params, {"collocation_loss": closs, "rollout_l1": rloss}


def train_hp_resnet(seed: int = 1, steps=3000):
    """Recurrent-ResNet baseline (Fig. 3j): h_{t+1} = h_t + g([v_t; h_t]).

    Same parameter population as the neural ODE, but it parameterises a
    single *discrete* transition at the sampling interval — the paper's
    stand-in for conventional finite-depth digital twins.
    """
    key = jax.random.PRNGKey(seed)
    params = model.init_params(model.HP_LAYERS, key)
    dt = datasets.HP_DT
    n = datasets.HP_NPOINTS
    pairs_in, pairs_out = [], []
    for name in ("sine", "triangular"):
        v_fn = datasets.STIMULI[name]
        t, v, h, _ = datasets.simulate_hp(v_fn, n_points=n, dt=dt)
        pairs_in.append(np.stack([v[:-1], h[:-1]], axis=-1))
        pairs_out.append((h[1:] - h[:-1])[:, None])
    u = jnp.asarray(np.concatenate(pairs_in), jnp.float32)
    dy = jnp.asarray(np.concatenate(pairs_out), jnp.float32)

    def loss(p):
        pred = model.field_driven(p, u[:, 1:2], u[:, 0:1])
        return jnp.mean(jnp.abs(pred - dy))

    params, final = _fit(loss, params, steps, 3e-3, 500, "hp-resnet")
    return params, {"next_step_l1": final}


# ---------------------------------------------------------------------------
# Lorenz96 neural ODE (Fig. 4)
# ---------------------------------------------------------------------------


def train_l96_node(
    seed: int = 0,
    colloc_steps=25000,
    rollout_steps=400,
    noise_std=0.01,
    hidden=64,
):
    """Train the autonomous Lorenz96 twin f(h) ~ dh/dt in *normalized*
    coordinates (states / L96_SCALE — see datasets.py on the paper's
    convention).

    Collocation states come from the *training* (interpolation) segment only,
    jittered with Gaussian noise — the paper's noise regularisation — so the
    learned field is accurate in a tube around the attractor, which is what
    extrapolation requires. A cosine learning-rate decay drives the field
    error low enough to track several Lyapunov times. Fine-tuning backprops
    through K-step RK4 windows.
    """
    layers = (datasets.L96_DIM, hidden, hidden, datasets.L96_DIM)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(layers, key)

    traj = datasets.simulate_lorenz96_normalized()[
        : datasets.L96_TRAIN_POINTS
    ]
    x = jnp.asarray(traj, jnp.float32)
    key, sub = jax.random.split(key)
    # Noise-regularised collocation set (16x augmentation).
    reps = 16
    xa = jnp.tile(x, (reps, 1))
    xa = xa + noise_std * jax.random.normal(sub, xa.shape)
    ta = jnp.asarray(
        datasets.lorenz96_field_normalized(np.asarray(xa)), jnp.float32
    )

    # Squared loss + cosine-decayed lr converges far tighter than plain L1
    # (we report the L1 for comparability).
    state = adam_init(params)

    @jax.jit
    def train_step(p, s, lr):
        def loss_fn(pp):
            pred = model.field_autonomous(pp, xa)
            return jnp.mean((pred - ta) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, s2 = adam_update(p, grads, s, lr=lr)
        return p2, s2, loss

    lr0, lr1 = 3e-3, 3e-5
    for k in range(colloc_steps):
        frac = k / max(colloc_steps - 1, 1)
        lr = lr1 + 0.5 * (lr0 - lr1) * (1 + np.cos(np.pi * frac))
        params, state, loss = train_step(params, state, lr)
        if k % 2000 == 0 or k == colloc_steps - 1:
            print(f"  [l96-colloc] step {k:5d} mse {float(loss):.6f}")
    pred = model.field_autonomous(params, xa)
    closs = float(jnp.mean(jnp.abs(pred - ta)))

    # --- multi-shot rollout fine-tune: 30-step windows through RK4
    dt = datasets.L96_DT
    win = 30
    n_win = (x.shape[0] - 1) // win
    starts = x[: n_win * win : win]
    segs = jnp.stack(
        [x[i * win : i * win + win + 1] for i in range(n_win)]
    )  # [n_win, win+1, d]

    def rollout_loss(p):
        pred = jax.vmap(
            lambda h0: model.rollout_autonomous_ref(p, h0, win, dt)
        )(starts)
        return jnp.mean(jnp.abs(pred - segs))

    params, rloss = _fit(
        rollout_loss, params, rollout_steps, 1e-4, 100, "l96-rollout"
    )
    return params, {"collocation_l1": closs, "rollout_l1": rloss}


# ---------------------------------------------------------------------------
# Recurrent baselines for Lorenz96 (Fig. 4g): RNN / GRU / LSTM
# ---------------------------------------------------------------------------


def init_rnn(kind: str, d_in: int, hidden: int, key):
    """Weight init for the three recurrent cells (flax is unavailable)."""
    gates = {"rnn": 1, "gru": 3, "lstm": 4}[kind]
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(np.sqrt(1.0 / d_in))
    s_h = float(np.sqrt(1.0 / hidden))
    return {
        "wx": jax.random.uniform(
            k1, (d_in, gates * hidden), jnp.float32, -s_in, s_in
        ),
        "wh": jax.random.uniform(
            k2, (hidden, gates * hidden), jnp.float32, -s_h, s_h
        ),
        "b": jnp.zeros((gates * hidden,), jnp.float32),
        "wo": jax.random.uniform(
            k3, (hidden, d_in), jnp.float32, -s_h, s_h
        ),
        "bo": jnp.zeros((d_in,), jnp.float32),
    }


def rnn_cell(kind: str, p, h, c, x):
    """One step of the cell; returns (h', c'). Standard formulations —
    the Rust inference implementations in ``rust/src/models/`` follow these
    equations exactly (gate order: RNN tanh; GRU z|r|n; LSTM i|f|g|o)."""
    z = jnp.matmul(x, p["wx"]) + jnp.matmul(h, p["wh"]) + p["b"]
    n_h = h.shape[-1]
    if kind == "rnn":
        return jnp.tanh(z), c
    if kind == "gru":
        zg = jax.nn.sigmoid(z[..., :n_h])
        rg = jax.nn.sigmoid(z[..., n_h : 2 * n_h])
        # candidate uses the *reset-gated* hidden state for its recurrent term
        nx = jnp.matmul(x, p["wx"][:, 2 * n_h :])
        nh = jnp.matmul(rg * h, p["wh"][:, 2 * n_h :])
        ng = jnp.tanh(nx + nh + p["b"][2 * n_h :])
        return (1 - zg) * ng + zg * h, c
    if kind == "lstm":
        i = jax.nn.sigmoid(z[..., :n_h])
        f = jax.nn.sigmoid(z[..., n_h : 2 * n_h])
        g = jnp.tanh(z[..., 2 * n_h : 3 * n_h])
        o = jax.nn.sigmoid(z[..., 3 * n_h :])
        c2 = f * c + i * g
        return o * jnp.tanh(c2), c2
    raise ValueError(kind)


def rnn_rollout(kind: str, p, xs, teacher_forcing: bool):
    """Run the cell over a sequence; emits next-state predictions
    x_{t+1} = x_t + Wo h_t (residual head, as in the Rust port)."""
    hidden = p["wh"].shape[0]
    h0 = jnp.zeros((hidden,), jnp.float32)
    c0 = jnp.zeros((hidden,), jnp.float32)

    if teacher_forcing:

        def body(carry, x):
            h, c = carry
            h2, c2 = rnn_cell(kind, p, h, c, x)
            pred = x + jnp.matmul(h2, p["wo"]) + p["bo"]
            return (h2, c2), pred

        _, preds = jax.lax.scan(body, (h0, c0), xs)
        return preds

    def body(carry, _):
        h, c, x = carry
        h2, c2 = rnn_cell(kind, p, h, c, x)
        pred = x + jnp.matmul(h2, p["wo"]) + p["bo"]
        return (h2, c2, pred), pred

    _, preds = jax.lax.scan(body, (h0, c0, xs[0]), None, length=xs.shape[0])
    return preds


def train_l96_rnn(kind: str, seed: int = 2, steps=2000, hidden=64,
                  input_noise=0.02):
    """Teacher-forced next-step training on the (normalized) interpolation
    segment — same data convention as the neural ODE.

    Gaussian input noise during teacher forcing is the standard fix for
    autoregressive divergence (the model learns to contract back onto the
    attractor from slightly-off states); without it the vanilla RNN
    explodes in free-running rollout."""
    key = jax.random.PRNGKey(seed + hash(kind) % 1000)
    p = init_rnn(kind, datasets.L96_DIM, hidden, key)
    traj = datasets.simulate_lorenz96_normalized()[
        : datasets.L96_TRAIN_POINTS
    ]
    xs = jnp.asarray(traj[:-1], jnp.float32)
    ys = jnp.asarray(traj[1:], jnp.float32)
    noise_key = jax.random.PRNGKey(seed + 777)
    noises = input_noise * jax.random.normal(
        noise_key, (8,) + xs.shape
    )

    def loss(pp):
        # Average over a small ensemble of noise draws (fixed for
        # determinism/jit caching).
        def one(n):
            preds = rnn_rollout(kind, pp, xs + n, teacher_forcing=True)
            return jnp.mean(jnp.abs(preds - ys))

        return jnp.mean(jax.vmap(one)(noises))

    p, final = _fit(loss, p, steps, 2e-3, 300, f"l96-{kind}")
    return p, {"next_step_l1": final}


# ---------------------------------------------------------------------------
# Serialisation — plain JSON so the Rust side needs no protobuf/np
# ---------------------------------------------------------------------------


def params_to_json(params, meta: dict) -> dict:
    return {
        "meta": meta,
        "layers": [
            {"w": np.asarray(w).tolist(), "b": np.asarray(b).tolist()}
            for w, b in params
        ],
    }


def rnn_to_json(p, meta: dict) -> dict:
    return {
        "meta": meta,
        "wx": np.asarray(p["wx"]).tolist(),
        "wh": np.asarray(p["wh"]).tolist(),
        "b": np.asarray(p["b"]).tolist(),
        "wo": np.asarray(p["wo"]).tolist(),
        "bo": np.asarray(p["bo"]).tolist(),
    }


def json_to_params(obj: dict):
    return [
        (
            jnp.asarray(layer["w"], jnp.float32),
            jnp.asarray(layer["b"], jnp.float32),
        )
        for layer in obj["layers"]
    ]


def save_json(obj: dict, path):
    with open(path, "w") as f:
        json.dump(obj, f)
